//! Vyukov bounded MPMC ring — §2.3.2: "delivers near-O(1) operations
//! with strict per-slot FIFO but requires capacity to be fixed at
//! initialization, sacrificing unboundedness." Per-slot sequence
//! numbers arbitrate producers and consumers without locks.

use std::cell::UnsafeCell;
use std::future::Future;
use std::mem::MaybeUninit;
use std::pin::Pin;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::task::{Context, Poll};

use crossbeam_utils::CachePadded;

use crate::queue::{BoxFuture, ConcurrentQueue};
use crate::util::wait::{WaitStrategy, WakerRegistration};

struct Slot<T> {
    /// Sequence protocol: `seq == pos` ⇒ writable by the enqueuer of
    /// `pos`; `seq == pos + 1` ⇒ readable by the dequeuer of `pos`;
    /// `seq == pos + cap` ⇒ consumed, writable next lap.
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free MPMC queue (fixed capacity, power of two).
pub struct VyukovQueue<T> {
    slots: Box<[Slot<T>]>,
    mask: usize,
    enqueue_pos: CachePadded<AtomicUsize>,
    dequeue_pos: CachePadded<AtomicUsize>,
    /// Producer-side eventcount: `push_async` futures of a full ring
    /// park here; every successful pop notifies, so an awaiting
    /// producer wakes as soon as capacity exists (no timer polling).
    producers: WaitStrategy,
}

unsafe impl<T: Send> Send for VyukovQueue<T> {}
unsafe impl<T: Send> Sync for VyukovQueue<T> {}

impl<T: Send> VyukovQueue<T> {
    /// Capacity is rounded up to the next power of two (min 2).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Vec<Slot<T>> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        VyukovQueue {
            slots: slots.into_boxed_slice(),
            mask: cap - 1,
            enqueue_pos: CachePadded::new(AtomicUsize::new(0)),
            dequeue_pos: CachePadded::new(AtomicUsize::new(0)),
            producers: WaitStrategy::new(),
        }
    }

    /// Ring capacity (rounded up to a power of two at construction).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Enqueue; `Err(item)` when the ring is full.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut pos = self.enqueue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                match self.enqueue_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.val.get()).write(item) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(now) => pos = now,
                }
            } else if diff < 0 {
                return Err(item); // full
            } else {
                pos = self.enqueue_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Dequeue; `None` when the ring is empty.
    pub fn pop(&self) -> Option<T> {
        let mut pos = self.dequeue_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - (pos + 1) as isize;
            if diff == 0 {
                match self.dequeue_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                ) {
                    Ok(_) => {
                        let v = unsafe { (*slot.val.get()).assume_init_read() };
                        slot.seq
                            .store(pos + self.mask + 1, Ordering::Release);
                        // The freed slot is capacity: wake any producer
                        // awaiting it in `push_async` (single fence +
                        // relaxed load when nobody waits).
                        self.producers.notify_if_waiting();
                        return Some(v);
                    }
                    Err(now) => pos = now,
                }
            } else if diff < 0 {
                return None; // empty
            } else {
                pos = self.dequeue_pos.load(Ordering::Relaxed);
            }
        }
    }
}

/// Future behind [`VyukovQueue`]'s `push_async` override: parks on the
/// producer-side eventcount and is woken by the pop that frees a slot,
/// following the same register → re-try → `Pending` protocol as the
/// CMP pop futures (the re-try after registration is the lost-wakeup
/// guard — a pop landing between the failed push and the registration
/// is observed by the second attempt).
struct PushFuture<'a, T: Send> {
    queue: &'a VyukovQueue<T>,
    item: Option<T>,
    registration: WakerRegistration,
}

// The item is moved out by value on the successful attempt; nothing is
// structurally pinned.
impl<T: Send> Unpin for PushFuture<'_, T> {}

impl<T: Send> Future for PushFuture<'_, T> {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        let this = &mut *self;
        let item = this.item.take().expect("push future polled after completion");
        let item = match this.queue.push(item) {
            Ok(()) => {
                this.registration.clear(&this.queue.producers);
                return Poll::Ready(());
            }
            Err(item) => item,
        };
        this.registration.ensure(&this.queue.producers, cx.waker());
        match this.queue.push(item) {
            Ok(()) => {
                this.registration.clear(&this.queue.producers);
                Poll::Ready(())
            }
            Err(item) => {
                this.item = Some(item);
                Poll::Pending
            }
        }
    }
}

impl<T: Send> Drop for PushFuture<'_, T> {
    fn drop(&mut self) {
        self.registration.clear(&self.queue.producers);
    }
}

impl<T: Send> ConcurrentQueue<T> for VyukovQueue<T> {
    fn try_enqueue(&self, item: T) -> Result<(), T> {
        self.push(item)
    }

    fn try_dequeue(&self) -> Option<T> {
        self.pop()
    }

    fn push_async(&self, item: T) -> BoxFuture<'_, ()> {
        Box::pin(PushFuture {
            queue: self,
            item: Some(item),
            registration: WakerRegistration::new(),
        })
    }

    fn name(&self) -> &'static str {
        "vyukov"
    }

    fn is_strict_fifo(&self) -> bool {
        true // per-slot FIFO on a single ring
    }

    fn is_lock_free(&self) -> bool {
        true
    }

    fn is_bounded(&self) -> bool {
        true
    }
}

impl<T> Drop for VyukovQueue<T> {
    fn drop(&mut self) {
        // Drop any unconsumed payloads.
        let mut pos = *self.dequeue_pos.get_mut();
        let end = *self.enqueue_pos.get_mut();
        while pos < end {
            let slot = &mut self.slots[pos & self.mask];
            // Only slots whose write completed (seq == pos+1) hold data.
            if *slot.seq.get_mut() == pos + 1 {
                unsafe { (*slot.val.get()).assume_init_drop() };
            }
            pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn capacity_rounds_to_power_of_two() {
        let q: VyukovQueue<u8> = VyukovQueue::new(100);
        assert_eq!(q.capacity(), 128);
        let q: VyukovQueue<u8> = VyukovQueue::new(1);
        assert_eq!(q.capacity(), 2);
    }

    #[test]
    fn fifo_until_full_then_err() {
        let q: VyukovQueue<u32> = VyukovQueue::new(4);
        for i in 0..4 {
            assert!(q.push(i).is_ok());
        }
        assert_eq!(q.push(99), Err(99), "full");
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn wraparound_many_laps() {
        let q: VyukovQueue<u64> = VyukovQueue::new(8);
        for lap in 0..1000u64 {
            for i in 0..8 {
                q.push(lap * 8 + i).unwrap();
            }
            for i in 0..8 {
                assert_eq!(q.pop(), Some(lap * 8 + i));
            }
        }
    }

    #[test]
    fn drop_releases_unconsumed() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        DROPS.store(0, Ordering::Relaxed);
        {
            let q: VyukovQueue<D> = VyukovQueue::new(8);
            for _ in 0..5 {
                q.push(D).ok().unwrap();
            }
            drop(q.pop());
        }
        assert_eq!(DROPS.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn push_async_parks_until_pop_frees_slot() {
        use crate::util::executor::block_on;
        use std::time::Duration;
        let q = Arc::new(VyukovQueue::<u32>::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(9), Err(9), "full");
        let q2 = q.clone();
        let producer = std::thread::spawn(move || block_on(q2.push_async(3)));
        std::thread::sleep(Duration::from_millis(20));
        // The pop's notify (not a timer) is what completes the future.
        assert_eq!(q.pop(), Some(1));
        producer.join().unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.producers.registered_wakers(), 0, "slot released");
    }

    #[test]
    fn dropped_push_future_releases_registration() {
        use std::pin::Pin;
        use std::task::{Context, Poll, Wake, Waker};
        struct Noop;
        impl Wake for Noop {
            fn wake(self: Arc<Self>) {}
        }
        let q = VyukovQueue::<u32>::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        let waker = Waker::from(Arc::new(Noop));
        let mut cx = Context::from_waker(&waker);
        {
            let mut fut = q.push_async(3);
            assert!(Pin::new(&mut fut).poll(&mut cx) == Poll::Pending);
            assert_eq!(q.producers.registered_wakers(), 1);
        } // dropped pending: the item and the slot both go
        assert_eq!(q.producers.registered_wakers(), 0);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None, "the abandoned 3 was dropped, not enqueued");
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q = Arc::new(VyukovQueue::<u64>::new(1024));
        let per = 5000u64;
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let producers: Vec<_> = (0..3u64)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        let mut v = p * per + i;
                        loop {
                            match q.push(v) {
                                Ok(()) => break,
                                Err(back) => {
                                    v = back;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let done = done.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop() {
                            Some(v) => got.push(v),
                            None => {
                                if done.load(Ordering::Acquire) && q.pop().is_none() {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                        }
                    }
                    got
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        assert_eq!(all.len() as u64, 3 * per);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, 3 * per);
    }
}
