//! The §3.4 ablation queue: the CMP structure with the **original M&S
//! helping mechanism re-enabled** on the enqueue path. Comparing this
//! against plain CMP isolates exactly the variable the paper discusses
//! ("eliminating helping reduces both the number of atomic operations
//! and cache line bouncing") with everything else held constant.

use crate::queue::cmp::{CmpConfig, CmpQueue};
use crate::queue::ConcurrentQueue;

/// CMP queue with M&S-style helping (ABL-HELP comparator).
pub struct MsHelpingQueue<T: Send> {
    inner: CmpQueue<T>,
}

impl<T: Send> Default for MsHelpingQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> MsHelpingQueue<T> {
    /// An empty queue with the default CMP configuration plus helping.
    pub fn new() -> Self {
        Self::with_config(CmpConfig::default())
    }

    /// Any CMP configuration, with helping forced on.
    pub fn with_config(cfg: CmpConfig) -> Self {
        MsHelpingQueue {
            inner: CmpQueue::with_config(cfg.with_helping()),
        }
    }

    /// Enqueue through the helping-enabled CMP core.
    pub fn push(&self, item: T) -> Result<(), T> {
        self.inner.push(item)
    }

    /// Dequeue; `None` when empty at the linearization point.
    pub fn pop(&self) -> Option<T> {
        self.inner.pop()
    }

    /// Access the underlying CMP queue (stats, reclamation).
    pub fn inner(&self) -> &CmpQueue<T> {
        &self.inner
    }
}

impl<T: Send> ConcurrentQueue<T> for MsHelpingQueue<T> {
    fn try_enqueue(&self, item: T) -> Result<(), T> {
        self.inner.push(item)
    }

    fn try_dequeue(&self) -> Option<T> {
        self.inner.pop()
    }

    fn name(&self) -> &'static str {
        "ms-helping"
    }

    fn is_strict_fifo(&self) -> bool {
        true
    }

    fn is_lock_free(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn helping_is_enabled() {
        let q: MsHelpingQueue<u32> = MsHelpingQueue::new();
        assert!(q.inner().config().helping);
    }

    #[test]
    fn fifo_preserved() {
        let q: MsHelpingQueue<u32> = MsHelpingQueue::new();
        for i in 0..300 {
            q.push(i).unwrap();
        }
        for i in 0..300 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn concurrent_smoke() {
        use std::sync::Arc;
        let q = Arc::new(MsHelpingQueue::<u64>::new());
        let producers: Vec<_> = (0..4u64)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        let mut n = 0;
        while q.pop().is_some() {
            n += 1;
        }
        assert_eq!(n, 4000);
    }
}
