//! Michael & Scott queue + epoch-based reclamation — the §2.2
//! comparator family (EBR/DEBRA). Identical linking discipline to
//! [`super::ms_hp`], but protection is a per-operation epoch pin instead
//! of per-pointer hazard publications. Cheaper per op than hazard
//! pointers, but reclamation stalls with any pinned thread (§2.3.1).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::atomic::{AtomicPtr, Ordering};

use crossbeam_utils::CachePadded;

use crate::queue::reclamation::ebr::{drop_box, EbrDomain};
use crate::queue::ConcurrentQueue;

struct MsNode<T> {
    next: AtomicPtr<MsNode<T>>,
    data: UnsafeCell<MaybeUninit<T>>,
}

impl<T> MsNode<T> {
    fn dummy() -> *mut Self {
        Box::into_raw(Box::new(MsNode {
            next: AtomicPtr::new(ptr::null_mut()),
            data: UnsafeCell::new(MaybeUninit::uninit()),
        }))
    }

    fn with_data(v: T) -> *mut Self {
        Box::into_raw(Box::new(MsNode {
            next: AtomicPtr::new(ptr::null_mut()),
            data: UnsafeCell::new(MaybeUninit::new(v)),
        }))
    }
}

/// M&S queue with EBR reclamation.
pub struct MsEbrQueue<T> {
    head: CachePadded<AtomicPtr<MsNode<T>>>,
    tail: CachePadded<AtomicPtr<MsNode<T>>>,
    domain: EbrDomain,
}

unsafe impl<T: Send> Send for MsEbrQueue<T> {}
unsafe impl<T: Send> Sync for MsEbrQueue<T> {}

impl<T: Send> Default for MsEbrQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> MsEbrQueue<T> {
    /// An empty queue with its own epoch domain.
    pub fn new() -> Self {
        let dummy = MsNode::<T>::dummy();
        MsEbrQueue {
            head: CachePadded::new(AtomicPtr::new(dummy)),
            tail: CachePadded::new(AtomicPtr::new(dummy)),
            domain: EbrDomain::new(),
        }
    }

    /// Reclamation diagnostics (FAULT experiment).
    pub fn domain(&self) -> &EbrDomain {
        &self.domain
    }

    /// Enqueue (always succeeds; the list is unbounded).
    pub fn push(&self, item: T) {
        let node = MsNode::with_data(item);
        let _guard = self.domain.pin();
        loop {
            let tail = self.tail.load(Ordering::Acquire);
            let next = unsafe { (*tail).next.load(Ordering::Acquire) };
            if tail != self.tail.load(Ordering::Acquire) {
                continue;
            }
            if !next.is_null() {
                let _ = self.tail.compare_exchange(
                    tail,
                    next,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                );
                continue;
            }
            if unsafe {
                (*tail)
                    .next
                    .compare_exchange(ptr::null_mut(), node, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            } {
                let _ = self
                    .tail
                    .compare_exchange(tail, node, Ordering::AcqRel, Ordering::Acquire);
                return;
            }
        }
    }

    /// Dequeue; `None` when empty at the linearization point.
    pub fn pop(&self) -> Option<T> {
        let _guard = self.domain.pin();
        loop {
            let head = self.head.load(Ordering::Acquire);
            let tail = self.tail.load(Ordering::Acquire);
            let next = unsafe { (*head).next.load(Ordering::Acquire) };
            if head != self.head.load(Ordering::Acquire) {
                continue;
            }
            if next.is_null() {
                return None;
            }
            if head == tail {
                let _ = self.tail.compare_exchange(
                    tail,
                    next,
                    Ordering::AcqRel,
                    Ordering::Acquire,
                );
                continue;
            }
            if self
                .head
                .compare_exchange(head, next, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                let data = unsafe { (*(*next).data.get()).assume_init_read() };
                unsafe { self.domain.retire(head, drop_box::<MsNode<T>>) };
                return Some(data);
            }
        }
    }
}

impl<T: Send> ConcurrentQueue<T> for MsEbrQueue<T> {
    fn try_enqueue(&self, item: T) -> Result<(), T> {
        self.push(item);
        Ok(())
    }

    fn try_dequeue(&self) -> Option<T> {
        self.pop()
    }

    fn name(&self) -> &'static str {
        "ms-ebr"
    }

    fn is_strict_fifo(&self) -> bool {
        true
    }

    fn is_lock_free(&self) -> bool {
        true // queue ops are lock-free; *reclamation* can stall (§2.2)
    }
}

impl<T> Drop for MsEbrQueue<T> {
    fn drop(&mut self) {
        unsafe {
            let mut cur = self.head.load(Ordering::Acquire);
            let mut is_dummy = true;
            while !cur.is_null() {
                let next = (*cur).next.load(Ordering::Acquire);
                if !is_dummy {
                    (*(*cur).data.get()).assume_init_drop();
                }
                drop(Box::from_raw(cur));
                cur = next;
                is_dummy = false;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn spsc_fifo() {
        let q: MsEbrQueue<u32> = MsEbrQueue::new();
        for i in 0..500 {
            q.push(i);
        }
        for i in 0..500 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q: Arc<MsEbrQueue<u64>> = Arc::new(MsEbrQueue::new());
        let done = Arc::new(AtomicBool::new(false));
        let per = 3000u64;
        let producers: Vec<_> = (0..3u64)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        q.push(p * per + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let done = done.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop() {
                            Some(v) => got.push(v),
                            None => {
                                if done.load(Ordering::Acquire) && q.pop().is_none() {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                        }
                    }
                    got
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        assert_eq!(all.len() as u64, 3 * per);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, 3 * per);
    }

    #[test]
    fn churn_reclaims_nodes() {
        let q: MsEbrQueue<u64> = MsEbrQueue::new();
        for i in 0..10_000 {
            q.push(i);
            q.pop();
        }
        assert!(q.domain().freed() > 0);
    }

    #[test]
    fn drop_with_live_items() {
        let q: MsEbrQueue<String> = MsEbrQueue::new();
        for i in 0..50 {
            q.push(format!("item-{i}"));
        }
        drop(q); // must not leak or double-free (asan-less smoke)
    }
}
