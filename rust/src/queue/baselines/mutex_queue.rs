//! Mutex-protected queue — the Intel TBB / Meta Folly stand-in
//! (§2.3.2: frameworks that "retain both FIFO and unbounded capacity by
//! introducing fine-grained or hybrid locks, but giving up lock-freedom
//! and incurring blocking overhead under contention").

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::queue::ConcurrentQueue;

/// Blocking FIFO queue: `Mutex<VecDeque>`.
pub struct MutexQueue<T> {
    inner: Mutex<VecDeque<T>>,
}

impl<T: Send> Default for MutexQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> MutexQueue<T> {
    /// An empty queue.
    pub fn new() -> Self {
        MutexQueue {
            inner: Mutex::new(VecDeque::new()),
        }
    }

    /// Enqueue under the lock (always succeeds).
    pub fn push(&self, item: T) {
        self.inner.lock().unwrap().push_back(item);
    }

    /// Dequeue under the lock; `None` when empty.
    pub fn pop(&self) -> Option<T> {
        self.inner.lock().unwrap().pop_front()
    }

    /// Current queue depth.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T: Send> ConcurrentQueue<T> for MutexQueue<T> {
    fn try_enqueue(&self, item: T) -> Result<(), T> {
        self.push(item);
        Ok(())
    }

    fn try_dequeue(&self) -> Option<T> {
        self.pop()
    }

    fn name(&self) -> &'static str {
        "mutex"
    }

    fn is_strict_fifo(&self) -> bool {
        true
    }

    fn is_lock_free(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q: MutexQueue<u32> = MutexQueue::new();
        for i in 0..100 {
            q.push(i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn len_tracks() {
        let q: MutexQueue<u8> = MutexQueue::new();
        assert!(q.is_empty());
        q.push(1);
        q.push(2);
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn mpmc_no_loss() {
        let q = Arc::new(MutexQueue::<u64>::new());
        let handles: Vec<_> = (0..4u64)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        q.push(p * 1000 + i);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut all = Vec::new();
        while let Some(v) = q.pop() {
            all.push(v);
        }
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4000);
    }
}
