//! Per-producer segmented queue — the "moodycamel ConcurrentQueue"
//! stand-in (§2.3.2: "excellent performance by using per-producer
//! segmented subqueues ... at the cost of strict FIFO: ordering is
//! preserved only within each producer, while interleaving between
//! producers is permitted").
//!
//! Architecture (a stand-in capturing the design the paper attributes
//! to moodycamel, not a port): each producer thread owns a sub-queue of
//! chained fixed-size rings it alone appends to; consumers round-robin
//! across sub-queues and claim slots with a CAS on the sub-queue's
//! `claimed` counter. Rings are only freed when the queue drops (ring
//! allocation takes a brief registry lock every `RING_CAP` items — the
//! hot path itself is lock-free).

use std::cell::{RefCell, UnsafeCell};
use std::mem::MaybeUninit;
use std::ptr;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crossbeam_utils::CachePadded;

use crate::queue::ConcurrentQueue;

/// Slots per ring segment.
pub const RING_CAP: usize = 2048;
/// Registry capacity: maximum distinct producer threads per queue.
pub const MAX_PRODUCERS: usize = 256;

/// Global id source so thread-local producer registrations can't alias
/// across queue instances that reuse an address.
static QUEUE_IDS: AtomicU64 = AtomicU64::new(1);

struct Ring<T> {
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    /// Items written and visible (single producer writes, Release).
    published: CachePadded<AtomicUsize>,
    /// Items claimed by consumers (CAS).
    claimed: CachePadded<AtomicUsize>,
    /// Producer moved on; `next` is set. Implies `published == RING_CAP`.
    sealed: AtomicBool,
    next: AtomicPtr<Ring<T>>,
}

impl<T> Ring<T> {
    fn new() -> Box<Self> {
        let slots: Vec<UnsafeCell<MaybeUninit<T>>> =
            (0..RING_CAP).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        Box::new(Ring {
            slots: slots.into_boxed_slice(),
            published: CachePadded::new(AtomicUsize::new(0)),
            claimed: CachePadded::new(AtomicUsize::new(0)),
            sealed: AtomicBool::new(false),
            next: AtomicPtr::new(ptr::null_mut()),
        })
    }
}

enum RingPop<T> {
    Got(T),
    Empty,
    Drained,
}

struct SubQueue<T> {
    /// Consumer-side: ring currently being drained.
    front: AtomicPtr<Ring<T>>,
    /// Producer-side: ring currently being filled (single writer).
    tail: AtomicPtr<Ring<T>>,
    /// Ownership of every ring ever chained (freed on queue drop only).
    rings: Mutex<Vec<*mut Ring<T>>>,
}

unsafe impl<T: Send> Send for SubQueue<T> {}
unsafe impl<T: Send> Sync for SubQueue<T> {}

impl<T: Send> SubQueue<T> {
    fn new() -> Box<Self> {
        let ring = Box::into_raw(Ring::new());
        Box::new(SubQueue {
            front: AtomicPtr::new(ring),
            tail: AtomicPtr::new(ring),
            rings: Mutex::new(vec![ring]),
        })
    }

    /// Producer-only append (single writer per sub-queue).
    fn push(&self, item: T) {
        unsafe {
            let mut ring = self.tail.load(Ordering::Relaxed);
            let mut pos = (*ring).published.load(Ordering::Relaxed);
            if pos == RING_CAP {
                // Chain a new ring: link first, then seal, then move the
                // producer tail (consumers observing `sealed` are thus
                // guaranteed to find `next`).
                let fresh = Box::into_raw(Ring::new());
                self.rings.lock().unwrap().push(fresh);
                (*ring).next.store(fresh, Ordering::Release);
                (*ring).sealed.store(true, Ordering::Release);
                self.tail.store(fresh, Ordering::Release);
                ring = fresh;
                pos = 0;
            }
            (*(*ring).slots[pos].get()).write(item);
            (*ring).published.store(pos + 1, Ordering::Release);
        }
    }

    fn pop_ring(ring: &Ring<T>) -> RingPop<T> {
        let mut c = ring.claimed.load(Ordering::Acquire);
        loop {
            let p = ring.published.load(Ordering::Acquire);
            if c >= p {
                return if ring.sealed.load(Ordering::Acquire) && c >= RING_CAP {
                    RingPop::Drained
                } else {
                    RingPop::Empty
                };
            }
            match ring.claimed.compare_exchange_weak(
                c,
                c + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    // Exclusive right to slot c (publish preceded claim).
                    let v = unsafe { (*ring.slots[c].get()).assume_init_read() };
                    return RingPop::Got(v);
                }
                Err(now) => c = now,
            }
        }
    }

    /// Consumer-side pop, advancing past drained rings.
    fn pop(&self) -> Option<T> {
        loop {
            let ring = self.front.load(Ordering::Acquire);
            match Self::pop_ring(unsafe { &*ring }) {
                RingPop::Got(v) => return Some(v),
                RingPop::Empty => return None,
                RingPop::Drained => {
                    let next = unsafe { (*ring).next.load(Ordering::Acquire) };
                    debug_assert!(!next.is_null(), "sealed ring must have next");
                    // Benign CAS: any one consumer advances the front.
                    let _ = self.front.compare_exchange(
                        ring,
                        next,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    );
                }
            }
        }
    }
}

impl<T> Drop for SubQueue<T> {
    fn drop(&mut self) {
        for &ring in self.rings.lock().unwrap().iter() {
            unsafe {
                let r = &*ring;
                let c = r.claimed.load(Ordering::Acquire);
                let p = r.published.load(Ordering::Acquire);
                for i in c..p {
                    (*r.slots[i].get()).assume_init_drop();
                }
                drop(Box::from_raw(ring));
            }
        }
    }
}

thread_local! {
    /// (queue id → sub-queue ptr) registrations for this thread.
    static PRODUCER_TLS: RefCell<Vec<(u64, usize)>> = const { RefCell::new(Vec::new()) };
}

/// Relaxed-FIFO MPMC queue with per-producer segmented sub-queues.
pub struct SegmentedQueue<T: Send> {
    id: u64,
    /// Published sub-queues, indexed densely `[0, count)`.
    registry: Box<[AtomicPtr<SubQueue<T>>]>,
    count: AtomicUsize,
    /// Ownership of the sub-queues.
    subs: Mutex<Vec<Box<SubQueue<T>>>>,
    /// Round-robin start hint for consumers.
    rotation: CachePadded<AtomicUsize>,
}

impl<T: Send> Default for SegmentedQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Send> SegmentedQueue<T> {
    /// An empty queue (producers self-register on first push).
    pub fn new() -> Self {
        let mut reg = Vec::with_capacity(MAX_PRODUCERS);
        reg.resize_with(MAX_PRODUCERS, || AtomicPtr::new(ptr::null_mut()));
        SegmentedQueue {
            id: QUEUE_IDS.fetch_add(1, Ordering::Relaxed),
            registry: reg.into_boxed_slice(),
            count: AtomicUsize::new(0),
            subs: Mutex::new(Vec::new()),
            rotation: CachePadded::new(AtomicUsize::new(0)),
        }
    }

    /// This thread's sub-queue, registering it on first use.
    fn my_subqueue(&self) -> *mut SubQueue<T> {
        PRODUCER_TLS.with(|tls| {
            let mut tls = tls.borrow_mut();
            if let Some(&(_, ptr)) = tls.iter().find(|(id, _)| *id == self.id) {
                return ptr as *mut SubQueue<T>;
            }
            let mut sub = SubQueue::new();
            let ptr: *mut SubQueue<T> = &mut *sub;
            let slot = self.count.load(Ordering::Relaxed);
            assert!(slot < MAX_PRODUCERS, "more than {MAX_PRODUCERS} producers");
            self.subs.lock().unwrap().push(sub);
            self.registry[slot].store(ptr, Ordering::Release);
            self.count.store(slot + 1, Ordering::Release);
            tls.push((self.id, ptr as usize));
            ptr
        })
    }

    /// Enqueue onto this thread's sub-queue (always succeeds).
    pub fn push(&self, item: T) {
        unsafe { (*self.my_subqueue()).push(item) }
    }

    /// Dequeue from the rotating sub-queue scan; `None` when every
    /// sub-queue looked empty (relaxed FIFO).
    pub fn pop(&self) -> Option<T> {
        let n = self.count.load(Ordering::Acquire);
        if n == 0 {
            return None;
        }
        let start = self.rotation.fetch_add(1, Ordering::Relaxed);
        for i in 0..n {
            let sub = self.registry[(start + i) % n].load(Ordering::Acquire);
            if sub.is_null() {
                continue;
            }
            if let Some(v) = unsafe { (*sub).pop() } {
                return Some(v);
            }
        }
        None
    }

    /// Number of registered producer sub-queues.
    pub fn producer_count(&self) -> usize {
        self.count.load(Ordering::Acquire)
    }
}

impl<T: Send> ConcurrentQueue<T> for SegmentedQueue<T> {
    fn try_enqueue(&self, item: T) -> Result<(), T> {
        self.push(item);
        Ok(())
    }

    fn try_dequeue(&self) -> Option<T> {
        self.pop()
    }

    fn name(&self) -> &'static str {
        "segmented"
    }

    fn is_strict_fifo(&self) -> bool {
        false // per-producer order only (§2.3.2)
    }

    fn is_lock_free(&self) -> bool {
        true // hot path; ring allocation locks briefly every RING_CAP ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn single_producer_order_preserved() {
        let q: SegmentedQueue<u32> = SegmentedQueue::new();
        let n = (3 * RING_CAP + 17) as u32; // crosses ring boundaries
        for i in 0..n {
            q.push(i);
        }
        for i in 0..n {
            assert_eq!(q.pop(), Some(i));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn producer_registration_is_per_thread() {
        let q = Arc::new(SegmentedQueue::<u32>::new());
        assert_eq!(q.producer_count(), 0);
        q.push(1);
        assert_eq!(q.producer_count(), 1);
        q.push(2);
        assert_eq!(q.producer_count(), 1, "same thread, same sub-queue");
        let q2 = q.clone();
        std::thread::spawn(move || q2.push(3)).join().unwrap();
        assert_eq!(q.producer_count(), 2);
    }

    #[test]
    fn two_queues_do_not_alias_registrations() {
        let a: SegmentedQueue<u32> = SegmentedQueue::new();
        let b: SegmentedQueue<u32> = SegmentedQueue::new();
        a.push(1);
        b.push(2);
        assert_eq!(a.pop(), Some(1));
        assert_eq!(b.pop(), Some(2));
    }

    #[test]
    fn per_producer_order_across_threads() {
        let q = Arc::new(SegmentedQueue::<(u8, u32)>::new());
        let per = 5000u32;
        let handles: Vec<_> = (0..3u8)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        q.push((p, i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut last = [-1i64; 3];
        let mut total = 0;
        while let Some((p, i)) = q.pop() {
            assert!(last[p as usize] < i as i64, "per-producer FIFO violated");
            last[p as usize] = i as i64;
            total += 1;
        }
        assert_eq!(total, 3 * per);
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        let q = Arc::new(SegmentedQueue::<u64>::new());
        let done = Arc::new(AtomicBool::new(false));
        let per = 4000u64;
        let producers: Vec<_> = (0..3u64)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        q.push(p * per + i);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let done = done.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop() {
                            Some(v) => got.push(v),
                            None => {
                                if done.load(Ordering::Acquire) && q.pop().is_none() {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                        }
                    }
                    got
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        assert_eq!(all.len() as u64, 3 * per, "no loss");
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, 3 * per, "no dup");
    }

    #[test]
    fn drop_releases_unconsumed_payloads() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct D;
        impl Drop for D {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::Relaxed);
            }
        }
        DROPS.store(0, Ordering::Relaxed);
        {
            let q: SegmentedQueue<D> = SegmentedQueue::new();
            for _ in 0..(RING_CAP + 10) {
                q.push(D);
            }
            drop(q.pop());
        }
        assert_eq!(DROPS.load(Ordering::Relaxed), RING_CAP + 10);
    }
}
