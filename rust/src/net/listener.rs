//! Accept loop, I/O thread pool, and the [`NetServer`] handle.
//!
//! Thread 0 runs the accept loop alongside connections; every I/O
//! thread runs a `conn_spawner` task pulling accepted sockets off a
//! *bounded* CMP handoff queue — the accept loop pushes with
//! [`push_async`](crate::queue::ConcurrentQueue::push_async), so a
//! full handoff suspends acceptance (kernel backlog absorbs the burst)
//! instead of growing without bound. Connections spread across threads
//! by whoever pops first.
//!
//! Shutdown: [`NetServer::shutdown`] sets the stop flag, kicks every
//! reactor, and joins the threads. Connections drain (pending replies
//! flush, then sockets close) while the inference [`Server`] is still
//! alive; only after every I/O thread exits is the server itself shut
//! down, and the net totals are folded into its [`ShutdownReport`].

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::conn::Conn;
use super::{NetConfig, NetMetrics, NetShared};
use crate::coordinator::server::{Server, ShutdownReport};
use crate::queue::cmp::{CmpConfig, CmpQueue};
use crate::queue::ConcurrentQueue;
use crate::util::executor::{Executor, LocalSpawner, Reactor};

/// How long a `conn_spawner` waits on the handoff queue before
/// re-checking the stop flag.
const SPAWNER_POLL: Duration = Duration::from_millis(100);

/// Handle to a running TCP front end. Dropping it without calling
/// [`NetServer::shutdown`] detaches the I/O threads (they keep serving
/// until the process exits); call `shutdown` for the graceful path.
pub struct NetServer {
    local_addr: SocketAddr,
    threads: Vec<JoinHandle<()>>,
    reactors: Vec<Reactor>,
    handoff: Arc<CmpQueue<TcpStream>>,
    shared: Arc<NetShared>,
    server: Arc<Server>,
}

/// Accept syscall wrapper carrying the `net/accept` fail point. An
/// injected fault is indistinguishable from a transient kernel error:
/// the connection stays in the backlog and is accepted on a later
/// pass, so no socket is ever lost to it.
fn accept_one(listener: &TcpListener) -> io::Result<(TcpStream, SocketAddr)> {
    crate::fail_point!(
        "net/accept",
        Err(io::Error::new(
            io::ErrorKind::ConnectionAborted,
            "injected accept fault",
        ))
    );
    listener.accept()
}

/// Accept task (thread 0 only): accepted sockets go nonblocking and
/// into the bounded handoff via `push_async` — the satellite
/// backpressure path. Parks on a reactor tick when the backlog is
/// empty.
async fn accept_loop(
    listener: TcpListener,
    handoff: Arc<CmpQueue<TcpStream>>,
    shared: Arc<NetShared>,
    reactor: Reactor,
) {
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            break;
        }
        match accept_one(&listener) {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    shared.metrics.accept_errors.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                let _ = stream.set_nodelay(true);
                shared.metrics.accepted.fetch_add(1, Ordering::Relaxed);
                shared.active_conns.fetch_add(1, Ordering::Relaxed);
                handoff.push_async(stream).await;
                reactor.note_progress();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                reactor.tick().await;
            }
            Err(_) => {
                // Transient (EMFILE, aborted handshake, injected
                // net/accept fault): count it and back off one tick.
                shared.metrics.accept_errors.fetch_add(1, Ordering::Relaxed);
                reactor.tick().await;
            }
        }
    }
}

/// Per-thread task turning handed-off sockets into [`Conn`] tasks on
/// this thread's executor. After stop, any sockets still queued are
/// dropped unserved (and accounted closed).
async fn conn_spawner(
    spawner: LocalSpawner,
    handoff: Arc<CmpQueue<TcpStream>>,
    server: Arc<Server>,
    shared: Arc<NetShared>,
    reactor: Reactor,
) {
    loop {
        let deadline = Instant::now() + SPAWNER_POLL;
        match handoff.pop_deadline_async(deadline).await {
            Some(stream) => {
                spawner.spawn(Conn::new(
                    stream,
                    server.clone(),
                    shared.clone(),
                    reactor.clone(),
                ));
            }
            None => {
                if shared.stop.load(Ordering::Relaxed) {
                    break;
                }
            }
        }
    }
    drain_handoff(&handoff, &shared);
}

/// Drop (and account) sockets that were accepted but never served —
/// the race window between the accept loop's last push and spawner
/// exit. Also the post-join backstop in [`NetServer::shutdown`].
fn drain_handoff(handoff: &CmpQueue<TcpStream>, shared: &NetShared) {
    while let Some(stream) = handoff.try_dequeue() {
        drop(stream);
        shared.metrics.closed.fetch_add(1, Ordering::Relaxed);
        shared.active_conns.fetch_sub(1, Ordering::Relaxed);
    }
}

fn io_thread(
    accept: Option<TcpListener>,
    handoff: Arc<CmpQueue<TcpStream>>,
    shared: Arc<NetShared>,
    server: Arc<Server>,
    reactor: Reactor,
) {
    let mut ex = Executor::new();
    let spawner = ex.spawner();
    if let Some(listener) = accept {
        ex.spawn(accept_loop(
            listener,
            handoff.clone(),
            shared.clone(),
            reactor.clone(),
        ));
    }
    ex.spawn(conn_spawner(spawner, handoff, server, shared, reactor));
    ex.run();
}

impl NetServer {
    /// Bind `cfg.addr` and start the I/O thread pool in front of
    /// `server`. The server is owned by the front end from here on —
    /// interact with it through [`NetServer::server`] and get it back
    /// (shut down) via [`NetServer::shutdown`].
    pub fn start(cfg: NetConfig, server: Server) -> io::Result<NetServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let io_threads = cfg.io_threads.max(1);
        let handoff_cap = cfg.handoff_capacity.max(1);
        let shared = Arc::new(NetShared::new(cfg));
        let server = Arc::new(server);
        // Bounded handoff: max_nodes caps occupancy (push_async parks
        // on full), and the small window keeps freed nodes reusable at
        // this capacity instead of idling in an unfilled batch.
        let handoff: Arc<CmpQueue<TcpStream>> = Arc::new(CmpQueue::with_config(
            CmpConfig::default()
                .with_max_nodes(handoff_cap)
                .with_window(64),
        ));
        let mut reactors = Vec::with_capacity(io_threads);
        let mut threads = Vec::with_capacity(io_threads);
        let mut listener = Some(listener);
        for i in 0..io_threads {
            let reactor = Reactor::new(shared.cfg.poll_min, shared.cfg.poll_max);
            reactors.push(reactor.clone());
            let accept = if i == 0 { listener.take() } else { None };
            let handoff = handoff.clone();
            let shared = shared.clone();
            let server = server.clone();
            let handle = std::thread::Builder::new()
                .name(format!("net-io-{i}"))
                .spawn(move || io_thread(accept, handoff, shared, server, reactor))
                .expect("spawn net I/O thread");
            threads.push(handle);
        }
        Ok(NetServer {
            local_addr,
            threads,
            reactors,
            handoff,
            shared,
            server,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The inference server behind the front end (metrics, in-process
    /// submits).
    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Socket-side counters.
    pub fn metrics(&self) -> &NetMetrics {
        &self.shared.metrics
    }

    /// Shared front-end state (tenant table, config, gauges).
    pub fn shared(&self) -> &NetShared {
        &self.shared
    }

    /// Cloned handle to the inference server for sidecars (the
    /// `/metrics` endpoint's render closure). Every clone must be
    /// dropped before [`NetServer::shutdown`], which reclaims unique
    /// ownership — shut the sidecar down first.
    pub fn server_handle(&self) -> Arc<Server> {
        self.server.clone()
    }

    /// Cloned handle to the shared front-end state (sidecars; no
    /// uniqueness requirement at shutdown, unlike
    /// [`NetServer::server_handle`]).
    pub fn shared_handle(&self) -> Arc<NetShared> {
        self.shared.clone()
    }

    /// Graceful stop: drain every connection (pending replies flush
    /// within the drain budget), join the I/O threads, then shut the
    /// inference server down. The returned report carries both the
    /// serving ledger and the net totals
    /// ([`ShutdownReport::net_conns_closed`],
    /// [`ShutdownReport::net_drained_replies`]).
    pub fn shutdown(self) -> ShutdownReport {
        self.shared.stop.store(true, Ordering::SeqCst);
        for r in &self.reactors {
            r.kick();
        }
        self.handoff.wake_consumers();
        for h in self.threads {
            let _ = h.join();
        }
        // Backstop for the accept-loop-push vs spawner-exit race: no
        // pushes can happen after the joins, so this empties for good.
        drain_handoff(&self.handoff, &self.shared);
        let server = match Arc::try_unwrap(self.server) {
            Ok(s) => s,
            Err(_) => panic!("net I/O threads joined but Server clones remain"),
        };
        let mut report = server.shutdown();
        let m = &self.shared.metrics;
        report.net_conns_closed = m.closed.load(Ordering::Relaxed);
        report.net_drained_replies = m.drained_replies.load(Ordering::Relaxed);
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::ServerConfig;
    use crate::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};

    fn echo_factory() -> EngineFactory {
        Arc::new(|| {
            Ok(Box::new(EchoEngine {
                batch: 4,
                features: 2,
                outputs: 1,
                scale: 2.0,
            }) as Box<dyn InferenceEngine>)
        })
    }

    #[test]
    fn start_and_shutdown_without_traffic() {
        let server = Server::start(ServerConfig::default(), echo_factory());
        let net = NetServer::start(NetConfig::default(), server).expect("bind");
        assert_ne!(net.addr().port(), 0, "ephemeral port resolved");
        let report = net.shutdown();
        assert!(report.clean(), "idle front end shuts down clean");
        assert_eq!(report.net_conns_closed, 0);
    }

    #[test]
    fn shutdown_accounts_connections_left_in_handoff() {
        use std::net::TcpStream as StdStream;
        let server = Server::start(ServerConfig::default(), echo_factory());
        let net = NetServer::start(NetConfig::default(), server).expect("bind");
        let addr = net.addr();
        // Park a few idle connections, give the accept loop a moment,
        // then shut down: every accepted socket must be accounted
        // closed, whether it became a Conn or died in the handoff.
        let clients: Vec<StdStream> = (0..4).map(|_| StdStream::connect(addr).unwrap()).collect();
        std::thread::sleep(Duration::from_millis(200));
        let accepted = net.metrics().accepted.load(Ordering::Relaxed);
        assert_eq!(accepted, 4, "all clients accepted");
        let report = net.shutdown();
        assert_eq!(report.net_conns_closed, 4, "accepted == closed");
        drop(clients);
    }
}
