//! One TCP connection as a hand-rolled future.
//!
//! [`Conn`] owns a nonblocking socket and runs a four-phase poll:
//! read + decode, poll in-flight responses, flush writes, check
//! deadlines. It parks on the shared [`Reactor`] (polled every tick)
//! *and* on each pending [`ResponseFuture`]'s slot waker, so replies
//! flush as soon as a worker completes them — ticks only bound the
//! latency of socket readiness and deadline checks.
//!
//! Lifecycle: `Open` → (`Draining`) → `Closed`. Draining starts on
//! shutdown, a protocol error, or a read-deadline expiry: the read side
//! stops, pending replies finish and flush, then the socket closes.
//! A dead peer (EOF, I/O error, stalled writes, drain overrun) skips
//! the drain: pending replies are *abandoned* at the socket while the
//! server completes them normally — the conservation ledger never
//! depends on a client staying alive. EOF is treated as a full
//! disconnect (no half-close protocol): clients must keep the socket
//! open until their replies arrive.

use std::future::Future;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::pin::Pin;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::task::{Context, Poll};
use std::time::Instant;

use super::codec::{self, Response, Status};
use super::NetShared;
use crate::coordinator::request::ResponseFuture;
use crate::coordinator::server::{Server, SubmitError};
use crate::util::executor::Reactor;

/// Read granularity per syscall; with [`MAX_READS_PER_POLL`] it bounds
/// how much one connection can consume in a single poll, so a firehose
/// peer cannot starve its siblings on the same I/O thread.
const READ_CHUNK: usize = 16 * 1024;
/// Max read syscalls per poll (see [`READ_CHUNK`]).
const MAX_READS_PER_POLL: usize = 4;

/// A request admitted to the server whose reply has not yet been
/// written back to the wire.
struct PendingReply {
    /// Client correlation id from the request frame.
    id: u64,
    /// Tenant holding the edge-admission slot to release.
    tenant: u32,
    /// Resolves when a worker completes the slot.
    fut: ResponseFuture,
}

/// One connection's future; spawned onto an I/O thread's executor by
/// the listener and polled to completion. Resolves `()` when the
/// socket is fully closed and accounted.
pub struct Conn {
    stream: TcpStream,
    server: Arc<Server>,
    shared: Arc<NetShared>,
    reactor: Reactor,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    write_pos: usize,
    unflushed_frames: u64,
    pending: Vec<PendingReply>,
    last_read_progress: Instant,
    last_write_progress: Instant,
    draining: bool,
    drain_started: Option<Instant>,
    peer_gone: bool,
}

/// Relaxed counter bump; metric sites below are hot-path adjacent, so
/// keep them to one call each.
fn inc(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}

/// Relaxed counter add (see [`inc`]).
fn add(c: &AtomicU64, n: u64) {
    c.fetch_add(n, Ordering::Relaxed);
}

/// Read syscall wrapper carrying the `net/read` fail point: an armed
/// `error` action surfaces as a connection reset, exercising the
/// abandon-in-flight path without a real network fault.
fn read_some(stream: &mut TcpStream, buf: &mut [u8]) -> io::Result<usize> {
    crate::fail_point!(
        "net/read",
        Err(io::Error::new(
            io::ErrorKind::ConnectionReset,
            "injected read fault",
        ))
    );
    stream.read(buf)
}

/// Write syscall wrapper carrying the `net/write` fail point.
fn write_some(stream: &mut TcpStream, buf: &[u8]) -> io::Result<usize> {
    crate::fail_point!(
        "net/write",
        Err(io::Error::new(
            io::ErrorKind::BrokenPipe,
            "injected write fault",
        ))
    );
    stream.write(buf)
}

impl Conn {
    /// Wrap an already-nonblocking accepted socket.
    pub fn new(
        stream: TcpStream,
        server: Arc<Server>,
        shared: Arc<NetShared>,
        reactor: Reactor,
    ) -> Self {
        let now = Instant::now();
        Conn {
            stream,
            server,
            shared,
            reactor,
            read_buf: Vec::new(),
            write_buf: Vec::new(),
            write_pos: 0,
            unflushed_frames: 0,
            pending: Vec::new(),
            last_read_progress: now,
            last_write_progress: now,
            draining: false,
            drain_started: None,
            peer_gone: false,
        }
    }

    fn write_done(&self) -> bool {
        self.write_pos == self.write_buf.len()
    }

    /// Stop reading; finish pending work, flush, then close.
    fn begin_drain(&mut self, now: Instant) {
        if !self.draining {
            self.draining = true;
            self.drain_started = Some(now);
        }
    }

    /// Latch the peer as dead (idempotent); callers count their own
    /// cause-specific metric before calling.
    fn mark_gone(&mut self) {
        self.peer_gone = true;
    }

    /// EOF: clean if nothing was outstanding, a disconnect otherwise.
    fn on_peer_eof(&mut self) {
        if self.peer_gone {
            return;
        }
        let outstanding =
            !self.pending.is_empty() || !self.read_buf.is_empty() || !self.write_done();
        if outstanding {
            inc(&self.shared.metrics.disconnects);
        }
        self.mark_gone();
    }

    /// Hard I/O error (real or injected): always a disconnect.
    fn on_peer_error(&mut self) {
        if self.peer_gone {
            return;
        }
        inc(&self.shared.metrics.disconnects);
        self.mark_gone();
    }

    /// Append a response frame, restarting the write-stall clock when
    /// the buffer was empty.
    fn queue_reply(&mut self, resp: &Response, now: Instant) {
        if self.write_done() {
            self.write_buf.clear();
            self.write_pos = 0;
            self.last_write_progress = now;
        }
        codec::encode_response(resp, &mut self.write_buf);
        self.unflushed_frames += 1;
    }

    /// Two-layer admission for one decoded request: the per-tenant edge
    /// cap first, then the server's global depth — both refusals answer
    /// `Busy` on the wire and land in the one shed ledger.
    fn admit(&mut self, req: codec::Request, now: Instant) {
        if !self.shared.tenants.try_admit(req.tenant) {
            inc(&self.shared.metrics.tenant_busy);
            inc(&self.shared.metrics.busy_replies);
            self.server.metrics().record_tenant_shed();
            self.queue_reply(
                &Response {
                    id: req.id,
                    status: Status::Busy,
                    output: vec![],
                },
                now,
            );
            return;
        }
        match self.server.submit_async_for_tenant(req.features, req.tenant) {
            Ok(fut) => self.pending.push(PendingReply {
                id: req.id,
                tenant: req.tenant,
                fut,
            }),
            Err(SubmitError::Overloaded) => {
                // The server already counted the shed; give back the
                // edge slot and tell the client to back off.
                self.shared.tenants.release(req.tenant);
                inc(&self.shared.metrics.busy_replies);
                self.queue_reply(
                    &Response {
                        id: req.id,
                        status: Status::Busy,
                        output: vec![],
                    },
                    now,
                );
            }
        }
    }

    /// Decode every complete frame in `read_buf`; a malformed frame
    /// poisons the connection (error notice + drain, rest discarded).
    fn decode_frames(&mut self, now: Instant) {
        let mut pos = 0;
        loop {
            match codec::decode_request(&self.read_buf[pos..]) {
                Ok(Some((req, used))) => {
                    pos += used;
                    inc(&self.shared.metrics.frames_in);
                    self.admit(req, now);
                }
                Ok(None) => break,
                Err(_) => {
                    inc(&self.shared.metrics.protocol_errors);
                    self.queue_reply(
                        &Response {
                            id: 0,
                            status: Status::Error,
                            output: vec![],
                        },
                        now,
                    );
                    self.begin_drain(now);
                    pos = self.read_buf.len();
                    break;
                }
            }
        }
        if pos > 0 {
            self.read_buf.drain(..pos);
        }
    }

    /// Pull bytes off the socket (bounded per poll) and decode.
    fn read_phase(&mut self, now: Instant) -> bool {
        let mut progress = false;
        let mut chunk = [0u8; READ_CHUNK];
        let mut reads = 0;
        while reads < MAX_READS_PER_POLL {
            reads += 1;
            match read_some(&mut self.stream, &mut chunk) {
                Ok(0) => {
                    self.on_peer_eof();
                    break;
                }
                Ok(n) => {
                    self.read_buf.extend_from_slice(&chunk[..n]);
                    self.last_read_progress = now;
                    progress = true;
                    if n < chunk.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.on_peer_error();
                    break;
                }
            }
        }
        if !self.peer_gone && !self.draining && !self.read_buf.is_empty() {
            self.decode_frames(now);
        }
        progress
    }

    /// Poll every in-flight response; completions are encoded into the
    /// write buffer and their tenant slots released.
    fn poll_pending(&mut self, cx: &mut Context<'_>, now: Instant) -> bool {
        let mut progress = false;
        let mut i = 0;
        while i < self.pending.len() {
            match Pin::new(&mut self.pending[i].fut).poll(cx) {
                Poll::Ready(resp) => {
                    let done = self.pending.swap_remove(i);
                    self.shared.tenants.release(done.tenant);
                    let wire = match resp.error {
                        None => Response {
                            id: done.id,
                            status: Status::Ok,
                            output: resp.output,
                        },
                        Some(_) => Response {
                            id: done.id,
                            status: Status::Error,
                            output: vec![],
                        },
                    };
                    self.queue_reply(&wire, now);
                    progress = true;
                }
                Poll::Pending => i += 1,
            }
        }
        progress
    }

    /// Flush as much of the write buffer as the socket accepts.
    fn write_phase(&mut self, now: Instant) -> bool {
        if self.peer_gone {
            return false;
        }
        let mut progress = false;
        while self.write_pos < self.write_buf.len() {
            match write_some(&mut self.stream, &self.write_buf[self.write_pos..]) {
                Ok(0) => {
                    self.on_peer_error();
                    break;
                }
                Ok(n) => {
                    self.write_pos += n;
                    self.last_write_progress = now;
                    progress = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.on_peer_error();
                    break;
                }
            }
        }
        if !self.peer_gone && self.write_done() && self.unflushed_frames > 0 {
            add(&self.shared.metrics.frames_out, self.unflushed_frames);
            if self.draining {
                add(&self.shared.metrics.drained_replies, self.unflushed_frames);
            }
            self.unflushed_frames = 0;
        }
        progress
    }

    /// Read/write/drain deadline enforcement — runs every poll, so a
    /// reactor tick is enough to time a dead or stalling peer out even
    /// with zero socket events.
    fn check_deadlines(&mut self, now: Instant) {
        if self.peer_gone {
            return;
        }
        if !self.draining
            && !self.read_buf.is_empty()
            && now.duration_since(self.last_read_progress) >= self.shared.cfg.read_timeout
        {
            // Slow-loris: a partial frame stalled past the read
            // deadline. Notify (id 0) and drain.
            inc(&self.shared.metrics.read_timeouts);
            self.queue_reply(
                &Response {
                    id: 0,
                    status: Status::Timeout,
                    output: vec![],
                },
                now,
            );
            self.read_buf.clear();
            self.begin_drain(now);
        }
        if !self.write_done()
            && now.duration_since(self.last_write_progress) >= self.shared.cfg.write_timeout
        {
            inc(&self.shared.metrics.write_timeouts);
            self.mark_gone();
            return;
        }
        if let Some(t0) = self.drain_started {
            if now.duration_since(t0) >= self.shared.cfg.drain_timeout {
                // Drain overran its budget: force the close. Pending
                // replies are abandoned (and counted) below.
                self.mark_gone();
            }
        }
    }

    /// Final accounting; runs exactly once, on the poll that returns
    /// `Ready`.
    fn finish(&mut self) {
        inc(&self.shared.metrics.closed);
        self.shared.active_conns.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Future for Conn {
    type Output = ();

    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        let this = &mut *self;
        let now = Instant::now();
        let mut progress = false;

        if this.shared.stop.load(Ordering::Relaxed) {
            this.begin_drain(now);
        }
        if !this.draining && !this.peer_gone {
            progress |= this.read_phase(now);
        }
        progress |= this.poll_pending(cx, now);
        progress |= this.write_phase(now);
        this.check_deadlines(now);

        if this.peer_gone {
            // Abandon in-flight replies at the socket: release the edge
            // slots and drop the futures. The server still completes
            // every slot (served or NACKed), so submitted == completed
            // holds without this client.
            for p in this.pending.drain(..) {
                this.shared.tenants.release(p.tenant);
                inc(&this.shared.metrics.abandoned_inflight);
            }
            let _ = this.stream.shutdown(Shutdown::Both);
            this.finish();
            return Poll::Ready(());
        }
        if this.draining && this.pending.is_empty() && this.write_done() {
            let _ = this.stream.shutdown(Shutdown::Both);
            this.finish();
            return Poll::Ready(());
        }

        if progress {
            this.reactor.note_progress();
        }
        // Always park on the reactor: the next tick re-polls us for
        // socket readiness and deadlines; slot wakers (registered via
        // poll_pending) fire earlier when replies complete.
        this.reactor.register(cx);
        Poll::Pending
    }
}
