//! Dependency-free Prometheus text `/metrics` endpoint (DESIGN.md §15).
//!
//! One extra thread runs the crate's own single-threaded
//! [`Executor`](crate::util::executor::Executor) with an adaptive
//! [`Reactor`](crate::util::executor::Reactor) — the same idiom as the
//! TCP ingress ([`super::listener`]) — serving a minimal HTTP/1.0
//! subset: `GET /metrics` returns the Prometheus text exposition
//! (version 0.0.4), everything else gets a 404. No HTTP library, no
//! keep-alive, no TLS: a scrape is one connection, one request, one
//! response, close.
//!
//! The endpoint is a pure *reader*: the render closure samples
//! published counters and gauges (relaxed atomic loads) on each scrape,
//! so scraping never touches the lock-free fast paths — the adaptive
//! control decisions it exports were already published out-of-band by
//! the control plane ([`crate::runtime::adaptive`]).

use std::collections::BTreeSet;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::server::Server;
use crate::queue::ConcurrentQueue;
use crate::util::executor::{Executor, LocalSpawner, Reactor};

use super::NetShared;

/// Renders the current exposition on every scrape. Captures whatever
/// handles it needs (e.g. an `Arc<Server>`); the serving thread owns
/// the closure, so joining the thread via [`MetricsServer::shutdown`]
/// releases those handles.
pub type RenderFn = Arc<dyn Fn() -> String + Send + Sync>;

/// Reactor tick floor while scrapes are making progress.
const POLL_MIN: Duration = Duration::from_micros(200);
/// Reactor tick ceiling while the endpoint is idle.
const POLL_MAX: Duration = Duration::from_millis(20);
/// Per-connection budget: a scrape that cannot finish reading its
/// request head and flushing the response within this long is dropped.
const SCRAPE_DEADLINE: Duration = Duration::from_secs(2);
/// Request heads larger than this are dropped (a scraper sends a few
/// hundred bytes; anything bigger is not a scraper).
const MAX_HEAD: usize = 8 * 1024;

/// Incremental builder for the Prometheus text exposition format.
///
/// Enforces the conventions the e2e tests pin: every family gets a
/// `# HELP` and `# TYPE` line, family names are unique per exposition,
/// and counters carry the `_total` suffix (appended here, so callers
/// pass the base name).
pub struct PromText {
    out: String,
    seen: BTreeSet<String>,
}

impl PromText {
    /// An empty exposition.
    pub fn new() -> Self {
        PromText {
            out: String::new(),
            seen: BTreeSet::new(),
        }
    }

    fn family(&mut self, name: &str, help: &str, kind: &str) {
        assert!(
            self.seen.insert(name.to_string()),
            "duplicate metric family {name}"
        );
        self.out
            .push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// Append a monotone counter; `_total` is appended to `name`.
    pub fn counter(&mut self, name: &str, help: &str, value: u64) {
        let full = format!("{name}_total");
        self.family(&full, help, "counter");
        self.out.push_str(&format!("{full} {value}\n"));
    }

    /// Append a gauge (point-in-time value, may go down).
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.family(name, help, "gauge");
        self.out.push_str(&format!("{name} {value}\n"));
    }

    /// Finish: the complete exposition body.
    pub fn render(self) -> String {
        self.out
    }
}

impl Default for PromText {
    fn default() -> Self {
        Self::new()
    }
}

/// Render the full exposition for a running pipeline: coordinator
/// counters, work-queue stats and adaptive-control decisions, and —
/// when the TCP ingress is present — the socket-side counters.
///
/// Every adaptive decision the control plane publishes is here:
/// `cmpq_spin_budget`, `cmpq_gap_ewma_seconds`, `cmpq_reclaim_p`,
/// `cmpq_park_ratio`, `cmpq_batch_fill`, `cmpq_batch_wait_seconds`.
pub fn render_prometheus(server: &Server, net: Option<&NetShared>) -> String {
    let mut p = PromText::new();
    let ld = |c: &std::sync::atomic::AtomicU64| c.load(Ordering::Relaxed);

    // Serving ledger (coordinator Metrics).
    let m = server.metrics();
    p.counter("cmpq_submitted", "Requests accepted by the server.", ld(&m.submitted));
    p.counter("cmpq_completed", "Responses delivered, including failures and NACKs.", ld(&m.completed));
    p.counter("cmpq_batches", "Model invocations executed.", ld(&m.batches));
    p.counter("cmpq_padding_rows", "Padded rows across all batches.", ld(&m.padding_rows));
    p.counter("cmpq_failures", "Failed inferences (engine errors).", ld(&m.failures));
    p.counter("cmpq_nacks", "Requests resolved with an explicit NACK.", ld(&m.nacks));
    p.counter("cmpq_deadline_expired", "Requests NACKed for an expired deadline.", ld(&m.deadline_expired));
    p.counter("cmpq_shed", "Requests refused at admission.", ld(&m.shed));
    p.counter("cmpq_shed_tenant", "Requests refused by the per-tenant edge cap.", ld(&m.shed_tenant));
    p.counter("cmpq_worker_panics", "Worker panics caught by supervision.", ld(&m.worker_panics));
    p.counter("cmpq_worker_restarts", "Supervisor-driven worker respawns.", ld(&m.worker_restarts));
    p.counter("cmpq_workers_dead", "Workers abandoned past the restart cap.", ld(&m.workers_dead));
    p.counter("cmpq_batcher_panics", "Batcher panics caught by the restart wrapper.", ld(&m.batcher_panics));
    p.counter("cmpq_batchers_dead", "Batchers abandoned past the restart cap.", ld(&m.batchers_dead));
    p.gauge("cmpq_workers_stalled", "Workers running but not heartbeating.", ld(&m.workers_stalled) as f64);
    p.gauge("cmpq_degraded", "1 when any supervised stage has been abandoned.", server.is_degraded() as u64 as f64);

    // Batcher control plane (written by observe_fill at each flush).
    p.gauge("cmpq_batch_fill", "EWMA of batch fill observed at flush (0-1).", ld(&m.batch_fill_permille) as f64 / 1000.0);
    p.gauge("cmpq_batch_wait_seconds", "Effective batcher flush deadline.", ld(&m.batch_wait_us) as f64 / 1e6);

    // Work queue: CMP stats plus the published adaptive decisions.
    let q = server.work_queue();
    let s = q.stats();
    p.counter("cmpq_wait_spins", "Spin iterations on the blocking wait path.", s.wait_spins);
    p.counter("cmpq_wait_parks", "Park registrations on the blocking wait path.", s.wait_parks);
    p.counter("cmpq_wait_sleeps", "Eventcount waits that reached the kernel-sleep loop.", q.wait_sleeps());
    p.counter("cmpq_reclaim_passes", "Completed reclamation passes.", s.reclaim_passes);
    p.counter("cmpq_nodes_reclaimed", "Nodes recycled to the pool.", s.nodes_reclaimed);
    p.gauge("cmpq_footprint_nodes", "Total nodes drawn from the OS by the work queue.", q.footprint_nodes() as f64);
    p.gauge("cmpq_nodes_in_use", "Work-queue nodes currently outside the freelist.", q.nodes_in_use() as f64);

    let snap = q.adaptive_snapshot();
    p.gauge("cmpq_spin_budget", "Learned spin steps before parking (0-7).", snap.spin_budget as f64);
    p.gauge("cmpq_gap_ewma_seconds", "Smoothed consumer inter-arrival gap.", snap.gap_ewma_ns as f64 / 1e9);
    if let Some(report) = q.control_report() {
        if let Some(pr) = report.park_ratio {
            p.gauge("cmpq_park_ratio", "Parks over parks-plus-spins on the wait path.", pr);
        }
        if let Some(rp) = report.reclaim_p {
            p.gauge("cmpq_reclaim_p", "Live Bernoulli reclamation probability.", rp);
        }
    }

    // Socket-side counters (TCP ingress only).
    if let Some(shared) = net {
        let n = &shared.metrics;
        p.counter("cmpq_net_accepted", "Connections accepted.", ld(&n.accepted));
        p.counter("cmpq_net_closed", "Connections fully closed.", ld(&n.closed));
        p.counter("cmpq_net_frames_in", "Request frames decoded.", ld(&n.frames_in));
        p.counter("cmpq_net_frames_out", "Response frames flushed.", ld(&n.frames_out));
        p.counter("cmpq_net_busy_replies", "Busy replies sent by either admission layer.", ld(&n.busy_replies));
        p.counter("cmpq_net_tenant_busy", "Busy replies from the per-tenant cap.", ld(&n.tenant_busy));
        p.counter("cmpq_net_read_timeouts", "Connections drained by the slow-loris deadline.", ld(&n.read_timeouts));
        p.counter("cmpq_net_write_timeouts", "Connections closed for stalled writes.", ld(&n.write_timeouts));
        p.counter("cmpq_net_disconnects", "Abnormal disconnects with work outstanding.", ld(&n.disconnects));
        p.counter("cmpq_net_abandoned_inflight", "In-flight responses whose connection died first.", ld(&n.abandoned_inflight));
        p.counter("cmpq_net_drained_replies", "Replies flushed during graceful drain.", ld(&n.drained_replies));
        p.counter("cmpq_net_protocol_errors", "Connections poisoned by undecodable bytes.", ld(&n.protocol_errors));
        p.counter("cmpq_net_accept_errors", "Accept-loop errors.", ld(&n.accept_errors));
        p.gauge("cmpq_net_active_conns", "Connections accepted but not yet closed.", ld(&shared.active_conns) as f64);
    }
    p.render()
}

/// Handle to a running `/metrics` endpoint. Call
/// [`MetricsServer::shutdown`] to stop it and release the render
/// closure's handles *before* tearing down whatever those handles
/// point at (e.g. before `NetServer::shutdown` reclaims unique
/// ownership of its `Server`). Dropping without `shutdown` detaches
/// the thread, mirroring [`super::listener::NetServer`].
pub struct MetricsServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    reactor: Reactor,
    thread: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (`host:port`; port 0 picks an ephemeral port) and
    /// serve `render`'s output at `GET /metrics`.
    pub fn start(addr: &str, render: RenderFn) -> io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let reactor = Reactor::new(POLL_MIN, POLL_MAX);
        let thread = {
            let (stop, reactor) = (stop.clone(), reactor.clone());
            std::thread::Builder::new()
                .name("metrics-http".into())
                .spawn(move || {
                    let mut ex = Executor::new();
                    let spawner = ex.spawner();
                    ex.spawn(scrape_accept_loop(listener, render, stop, reactor, spawner));
                    ex.run();
                })?
        };
        Ok(MetricsServer {
            local_addr,
            stop,
            reactor,
            thread: Some(thread),
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, join the serving thread, and drop the render
    /// closure (releasing every handle it captured).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.reactor.kick();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Accept loop: one task per scrape connection on the same executor.
async fn scrape_accept_loop(
    listener: TcpListener,
    render: RenderFn,
    stop: Arc<AtomicBool>,
    reactor: Reactor,
    spawner: LocalSpawner,
) {
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                spawner.spawn(serve_scrape(stream, render.clone(), reactor.clone()));
                reactor.note_progress();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                reactor.tick().await;
            }
            Err(_) => {
                reactor.tick().await;
            }
        }
    }
}

/// `true` iff the request line asks for `GET /metrics`.
fn wants_metrics(head: &[u8]) -> bool {
    let line = head.split(|&b| b == b'\r').next().unwrap_or(&[]);
    let Ok(line) = std::str::from_utf8(line) else {
        return false;
    };
    let mut parts = line.split_whitespace();
    parts.next() == Some("GET") && matches!(parts.next(), Some("/metrics") | Some("/metrics/"))
}

/// One scrape: read the request head, render, write, close.
async fn serve_scrape(mut stream: TcpStream, render: RenderFn, reactor: Reactor) {
    let deadline = Instant::now() + SCRAPE_DEADLINE;
    let mut head: Vec<u8> = Vec::with_capacity(256);
    let mut buf = [0u8; 512];
    let ok = loop {
        if Instant::now() >= deadline || head.len() > MAX_HEAD {
            return;
        }
        match stream.read(&mut buf) {
            Ok(0) => return,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                reactor.note_progress();
                if head.windows(4).any(|w| w == b"\r\n\r\n") {
                    break wants_metrics(&head);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                reactor.tick().await;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    };
    let (status, body) = if ok {
        ("200 OK", render())
    } else {
        ("404 Not Found", "not found\n".to_string())
    };
    let resp = format!(
        "HTTP/1.0 {status}\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    let mut bytes = resp.as_bytes();
    while !bytes.is_empty() {
        if Instant::now() >= deadline {
            return;
        }
        match stream.write(bytes) {
            Ok(0) => return,
            Ok(n) => {
                bytes = &bytes[n..];
                reactor.note_progress();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                reactor.tick().await;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prom_text_builds_valid_families() {
        let mut p = PromText::new();
        p.counter("cmpq_things", "Things counted.", 42);
        p.gauge("cmpq_level", "Current level.", 0.25);
        let out = p.render();
        assert!(out.contains("# TYPE cmpq_things_total counter\n"));
        assert!(out.contains("cmpq_things_total 42\n"));
        assert!(out.contains("# TYPE cmpq_level gauge\n"));
        assert!(out.contains("cmpq_level 0.25\n"));
        assert!(out.contains("# HELP cmpq_things_total Things counted.\n"));
    }

    #[test]
    #[should_panic(expected = "duplicate metric family")]
    fn prom_text_rejects_duplicate_families() {
        let mut p = PromText::new();
        p.gauge("cmpq_level", "Once.", 1.0);
        p.gauge("cmpq_level", "Twice.", 2.0);
    }

    #[test]
    fn request_line_parsing() {
        assert!(wants_metrics(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert!(wants_metrics(b"GET /metrics/ HTTP/1.0\r\n\r\n"));
        assert!(!wants_metrics(b"GET / HTTP/1.1\r\n\r\n"));
        assert!(!wants_metrics(b"POST /metrics HTTP/1.1\r\n\r\n"));
        assert!(!wants_metrics(b"\xff\xfe\r\n\r\n"));
    }

    #[test]
    fn scrape_roundtrip_and_404() {
        let hits = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let render: RenderFn = {
            let hits = hits.clone();
            Arc::new(move || {
                hits.fetch_add(1, Ordering::Relaxed);
                let mut p = PromText::new();
                p.counter("cmpq_scrapes", "Scrapes served.", 1);
                p.render()
            })
        };
        let ms = MetricsServer::start("127.0.0.1:0", render).expect("bind");
        let addr = ms.addr();

        let get = |path: &str| -> String {
            let mut c = TcpStream::connect(addr).expect("connect");
            write!(c, "GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
            let mut out = String::new();
            c.read_to_string(&mut out).expect("read reply");
            out
        };

        let ok = get("/metrics");
        assert!(ok.starts_with("HTTP/1.0 200 OK\r\n"), "got: {ok}");
        assert!(ok.contains("cmpq_scrapes_total 1"));
        assert!(ok.contains("Content-Type: text/plain; version=0.0.4"));

        let missing = get("/nope");
        assert!(missing.starts_with("HTTP/1.0 404 Not Found\r\n"));
        assert!(!missing.contains("cmpq_scrapes_total"));

        assert_eq!(hits.load(Ordering::Relaxed), 1, "404s never render");
        ms.shutdown();
    }
}
