//! Length-prefixed wire format for the TCP front end (DESIGN.md §12).
//!
//! Every frame starts with a little-endian `u32` byte length covering
//! everything *after* the length field itself. Payload layout:
//!
//! ```text
//! request:  [len: u32][id: u64][tenant: u32][n: u32][n × f32]
//! response: [len: u32][id: u64][status: u8][n: u32][n × f32]
//! ```
//!
//! `id` is a client-chosen correlation id echoed back verbatim —
//! responses may arrive out of request order (batching reorders), so
//! clients match on the id, never on position. All integers and floats
//! are little-endian.
//!
//! Decoding is incremental and allocation-bounded: `Ok(None)` means
//! "need more bytes" (the caller keeps accumulating), and any frame
//! whose declared length exceeds [`MAX_FRAME_BYTES`] — or whose
//! payload doesn't match its declared length — is a [`DecodeError`],
//! after which the connection is poisoned and drained (a malformed
//! stream has no resynchronization point).

/// Hard ceiling on the declared payload length of a single frame.
/// Anything larger is a protocol error, not an allocation: the guard
/// runs before any buffer is sized from attacker-controlled input.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Fixed part of a request payload: id (8) + tenant (4) + count (4).
const REQ_HEADER: usize = 16;
/// Fixed part of a response payload: id (8) + status (1) + count (4).
const RESP_HEADER: usize = 13;

/// Response status byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Served: the output rows follow.
    Ok,
    /// Shed by admission control (server depth or per-tenant cap):
    /// never enqueued, safe to retry after backoff.
    Busy,
    /// NACKed inside the pipeline (worker/batcher death, engine
    /// failure, deadline, shutdown): the request was admitted but
    /// could not be served.
    Error,
    /// The connection's read deadline expired mid-frame (slow-loris
    /// guard): sent with id 0 just before the server drains the
    /// connection.
    Timeout,
}

impl Status {
    /// Wire encoding of the status byte.
    pub fn as_u8(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::Busy => 1,
            Status::Error => 2,
            Status::Timeout => 3,
        }
    }

    /// Inverse of [`Status::as_u8`]; `None` for unknown bytes.
    pub fn from_u8(b: u8) -> Option<Status> {
        match b {
            0 => Some(Status::Ok),
            1 => Some(Status::Busy),
            2 => Some(Status::Error),
            3 => Some(Status::Timeout),
            _ => None,
        }
    }
}

/// A decoded request frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Client-chosen correlation id, echoed back in the response.
    pub id: u64,
    /// Tenant the request is billed to (admission fairness key).
    pub tenant: u32,
    /// Flattened feature row.
    pub features: Vec<f32>,
}

/// A decoded response frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    /// Correlation id of the request this answers (0 for
    /// connection-level [`Status::Timeout`] notices).
    pub id: u64,
    /// Outcome.
    pub status: Status,
    /// Output rows; empty unless `status` is [`Status::Ok`].
    pub output: Vec<f32>,
}

/// Why a byte stream stopped being a valid frame sequence. All
/// variants poison the connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeError {
    /// Declared payload length exceeds [`MAX_FRAME_BYTES`].
    Oversize(usize),
    /// Declared payload length contradicts the fixed header + element
    /// count it contains.
    Malformed,
    /// Unknown status byte in a response frame.
    BadStatus(u8),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Oversize(n) => write!(f, "frame of {n} bytes exceeds the cap"),
            DecodeError::Malformed => write!(f, "frame length contradicts its contents"),
            DecodeError::BadStatus(b) => write!(f, "unknown response status byte {b}"),
        }
    }
}

impl std::error::Error for DecodeError {}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn get_u32(buf: &[u8]) -> u32 {
    u32::from_le_bytes(buf[..4].try_into().expect("4 bytes"))
}

fn get_u64(buf: &[u8]) -> u64 {
    u64::from_le_bytes(buf[..8].try_into().expect("8 bytes"))
}

/// Append the wire encoding of `req` to `out`.
pub fn encode_request(req: &Request, out: &mut Vec<u8>) {
    let len = REQ_HEADER + 4 * req.features.len();
    put_u32(out, len as u32);
    put_u64(out, req.id);
    put_u32(out, req.tenant);
    put_u32(out, req.features.len() as u32);
    for f in &req.features {
        out.extend_from_slice(&f.to_le_bytes());
    }
}

/// Append the wire encoding of `resp` to `out`.
pub fn encode_response(resp: &Response, out: &mut Vec<u8>) {
    let len = RESP_HEADER + 4 * resp.output.len();
    put_u32(out, len as u32);
    put_u64(out, resp.id);
    out.push(resp.status.as_u8());
    put_u32(out, resp.output.len() as u32);
    for f in &resp.output {
        out.extend_from_slice(&f.to_le_bytes());
    }
}

/// Frame boundary scan shared by both decoders: `Ok(Some(payload))`
/// with the payload slice once the buffer holds a whole frame,
/// `Ok(None)` while bytes are still missing.
fn frame(buf: &[u8]) -> Result<Option<&[u8]>, DecodeError> {
    if buf.len() < 4 {
        return Ok(None);
    }
    let len = get_u32(buf) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(DecodeError::Oversize(len));
    }
    if buf.len() < 4 + len {
        return Ok(None);
    }
    Ok(Some(&buf[4..4 + len]))
}

/// Decode one request frame from the front of `buf`. Returns the
/// request and the total bytes consumed (length prefix included);
/// `Ok(None)` means the buffer holds only a partial frame.
pub fn decode_request(buf: &[u8]) -> Result<Option<(Request, usize)>, DecodeError> {
    let Some(payload) = frame(buf)? else {
        return Ok(None);
    };
    if payload.len() < REQ_HEADER {
        return Err(DecodeError::Malformed);
    }
    let id = get_u64(payload);
    let tenant = get_u32(&payload[8..]);
    let n = get_u32(&payload[12..]) as usize;
    if payload.len() != REQ_HEADER + 4 * n {
        return Err(DecodeError::Malformed);
    }
    let features = payload[REQ_HEADER..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
        .collect();
    Ok(Some((
        Request { id, tenant, features },
        4 + payload.len(),
    )))
}

/// Decode one response frame from the front of `buf` (client side).
/// Same contract as [`decode_request`].
pub fn decode_response(buf: &[u8]) -> Result<Option<(Response, usize)>, DecodeError> {
    let Some(payload) = frame(buf)? else {
        return Ok(None);
    };
    if payload.len() < RESP_HEADER {
        return Err(DecodeError::Malformed);
    }
    let id = get_u64(payload);
    let status = Status::from_u8(payload[8]).ok_or(DecodeError::BadStatus(payload[8]))?;
    let n = get_u32(&payload[9..]) as usize;
    if payload.len() != RESP_HEADER + 4 * n {
        return Err(DecodeError::Malformed);
    }
    let output = payload[RESP_HEADER..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
        .collect();
    Ok(Some((Response { id, status, output }, 4 + payload.len())))
}

/// Blocking client convenience: read from `r` (accumulating into
/// `buf`, which carries partial frames across calls) until one
/// complete response decodes. `None` on EOF, I/O error, or an
/// undecodable stream. Server-side code never blocks like this — it
/// exists for test clients, examples, and the CLI's client fleets.
pub fn read_response_blocking(
    r: &mut impl std::io::Read,
    buf: &mut Vec<u8>,
) -> Option<Response> {
    let mut chunk = [0u8; 4096];
    loop {
        match decode_response(buf) {
            Ok(Some((resp, used))) => {
                buf.drain(..used);
                return Some(resp);
            }
            Ok(None) => {}
            Err(_) => return None,
        }
        match r.read(&mut chunk) {
            Ok(0) => return None,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let req = Request {
            id: 42,
            tenant: 7,
            features: vec![1.5, -2.0, 0.25],
        };
        let mut buf = Vec::new();
        encode_request(&req, &mut buf);
        let (back, used) = decode_request(&buf).unwrap().unwrap();
        assert_eq!(back, req);
        assert_eq!(used, buf.len());
    }

    #[test]
    fn response_roundtrip_all_statuses() {
        for status in [Status::Ok, Status::Busy, Status::Error, Status::Timeout] {
            let resp = Response {
                id: 9,
                status,
                output: if status == Status::Ok { vec![3.0] } else { vec![] },
            };
            let mut buf = Vec::new();
            encode_response(&resp, &mut buf);
            let (back, used) = decode_response(&buf).unwrap().unwrap();
            assert_eq!(back, resp);
            assert_eq!(used, buf.len());
            assert_eq!(Status::from_u8(status.as_u8()), Some(status));
        }
        assert_eq!(Status::from_u8(200), None);
    }

    #[test]
    fn partial_frames_need_more_bytes() {
        let req = Request {
            id: 1,
            tenant: 0,
            features: vec![1.0, 2.0],
        };
        let mut buf = Vec::new();
        encode_request(&req, &mut buf);
        for cut in 0..buf.len() {
            assert_eq!(decode_request(&buf[..cut]).unwrap(), None, "cut={cut}");
        }
        assert!(decode_request(&buf).unwrap().is_some());
    }

    #[test]
    fn pipelined_frames_decode_in_sequence() {
        let mut buf = Vec::new();
        for id in 0..3u64 {
            encode_request(
                &Request {
                    id,
                    tenant: id as u32,
                    features: vec![id as f32],
                },
                &mut buf,
            );
        }
        let mut pos = 0;
        for id in 0..3u64 {
            let (req, used) = decode_request(&buf[pos..]).unwrap().unwrap();
            assert_eq!(req.id, id);
            assert_eq!(req.features, vec![id as f32]);
            pos += used;
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn oversize_frame_is_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&((MAX_FRAME_BYTES as u32) + 1).to_le_bytes());
        assert_eq!(
            decode_request(&buf),
            Err(DecodeError::Oversize(MAX_FRAME_BYTES + 1))
        );
    }

    #[test]
    fn malformed_lengths_are_rejected() {
        // Declared length smaller than the fixed header.
        let mut buf = Vec::new();
        buf.extend_from_slice(&8u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 8]);
        assert_eq!(decode_request(&buf), Err(DecodeError::Malformed));

        // Element count contradicting the declared length.
        let mut buf = Vec::new();
        let req = Request {
            id: 1,
            tenant: 0,
            features: vec![1.0],
        };
        encode_request(&req, &mut buf);
        buf[16] = 99; // inflate the element count, keep the length
        assert_eq!(decode_request(&buf), Err(DecodeError::Malformed));
    }

    #[test]
    fn blocking_reader_crosses_frames_and_reports_eof() {
        let resp = Response {
            id: 5,
            status: Status::Ok,
            output: vec![1.0, 2.0],
        };
        let mut wire = Vec::new();
        encode_response(&resp, &mut wire);
        encode_response(&resp, &mut wire);
        let mut cur = std::io::Cursor::new(wire);
        let mut buf = Vec::new();
        assert_eq!(read_response_blocking(&mut cur, &mut buf).unwrap(), resp);
        assert_eq!(read_response_blocking(&mut cur, &mut buf).unwrap(), resp);
        assert!(read_response_blocking(&mut cur, &mut buf).is_none(), "EOF");
    }

    #[test]
    fn bad_status_byte_is_rejected() {
        let mut buf = Vec::new();
        encode_response(
            &Response {
                id: 1,
                status: Status::Ok,
                output: vec![],
            },
            &mut buf,
        );
        buf[12] = 9; // status byte lives after len(4) + id(8)
        assert_eq!(decode_response(&buf), Err(DecodeError::BadStatus(9)));
    }
}
