//! Fault-tolerant TCP front end for the serving stack (DESIGN.md §12).
//!
//! A small pool of I/O threads — each running the crate's own
//! single-threaded [`Executor`](crate::util::executor::Executor) with an
//! adaptive polling [`Reactor`](crate::util::executor::Reactor) —
//! multiplexes tens of thousands of nonblocking connections onto a
//! handful of host threads. No epoll/mio dependency: readiness is
//! discovered by polling nonblocking sockets on reactor ticks whose
//! interval adapts between a configured min (busy) and max (idle).
//!
//! The accept loop hands fresh sockets to the I/O threads over a
//! *bounded* CMP queue using the backpressure-aware
//! [`push_async`](crate::queue::ConcurrentQueue::push_async), so an
//! accept storm suspends acceptance instead of ballooning memory.
//! Each connection is one [`conn::Conn`] future speaking the
//! length-prefixed [`codec`] wire format and feeding
//! [`Server::submit_async_for_tenant`](crate::coordinator::server::Server::submit_async_for_tenant).
//!
//! Robustness contract:
//!
//! * **Slow-loris**: a partial frame that stalls past the read deadline
//!   gets a `Timeout` notice and the connection is drained — the
//!   reactor is never blocked by one slow peer.
//! * **Disconnect mid-request**: in-flight responses are abandoned at
//!   the socket but complete normally server-side, so the conservation
//!   ledger (`submitted == completed`; shed counted separately) stays
//!   exact.
//! * **Overload**: two admission layers — a per-tenant in-flight cap at
//!   the edge ([`TenantTable`]) and the server's global `max_inflight` —
//!   both answer with a wire-level `Busy` reply instead of queueing.
//! * **Shutdown**: connections drain (pending replies flush) before the
//!   sockets close; the drain totals fold into
//!   [`ShutdownReport`](crate::coordinator::server::ShutdownReport).

pub mod codec;
pub mod conn;
pub mod listener;
pub mod metrics_http;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Tuning knobs for the TCP front end.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Listen address (`host:port`; port 0 binds an ephemeral port —
    /// read it back via [`listener::NetServer::addr`]).
    pub addr: String,
    /// I/O threads. Thread 0 also runs the accept loop; every thread
    /// runs connections. Tens of thousands of connections fit on a
    /// handful of threads.
    pub io_threads: usize,
    /// Reactor tick floor: the polling interval while connections are
    /// making progress.
    pub poll_min: Duration,
    /// Reactor tick ceiling: the polling interval backs off to this
    /// while every connection is idle.
    pub poll_max: Duration,
    /// Slow-loris guard: a connection holding a *partial* frame with no
    /// read progress for this long gets a `Timeout` notice and drains.
    pub read_timeout: Duration,
    /// A connection with unflushed reply bytes and no write progress
    /// for this long is treated as gone (its socket is closed).
    pub write_timeout: Duration,
    /// Draining connections (shutdown, protocol error, read timeout)
    /// that cannot finish flushing within this long are force-closed
    /// and their in-flight replies abandoned.
    pub drain_timeout: Duration,
    /// Per-tenant in-flight cap at the network edge (0 = unlimited).
    /// A tenant at its cap gets `Busy` replies while other tenants keep
    /// being admitted — one noisy tenant cannot starve the rest.
    pub tenant_max_inflight: usize,
    /// Capacity of the bounded accept→I/O handoff queue; accepting
    /// backpressures (via `push_async`) when it fills.
    pub handoff_capacity: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:0".to_string(),
            io_threads: 2,
            poll_min: Duration::from_micros(200),
            poll_max: Duration::from_millis(10),
            read_timeout: Duration::from_secs(2),
            write_timeout: Duration::from_secs(2),
            drain_timeout: Duration::from_secs(5),
            tenant_max_inflight: 0,
            handoff_capacity: 1024,
        }
    }
}

/// Counters for the network edge. Everything socket-side lives here;
/// request-side accounting stays in
/// [`Metrics`](crate::coordinator::metrics::Metrics) so the serving
/// ledger has a single owner.
#[derive(Default)]
pub struct NetMetrics {
    /// Connections accepted.
    pub accepted: AtomicU64,
    /// Connections fully closed (every accepted connection ends here,
    /// including those dropped unserved during shutdown).
    pub closed: AtomicU64,
    /// Request frames decoded.
    pub frames_in: AtomicU64,
    /// Response frames fully flushed to a socket.
    pub frames_out: AtomicU64,
    /// `Busy` replies sent (either admission layer).
    pub busy_replies: AtomicU64,
    /// `Busy` replies caused by the per-tenant cap specifically.
    pub tenant_busy: AtomicU64,
    /// Connections drained by the slow-loris read deadline.
    pub read_timeouts: AtomicU64,
    /// Connections closed for stalled writes.
    pub write_timeouts: AtomicU64,
    /// Connections that disconnected abnormally (EOF or I/O error with
    /// work still outstanding).
    pub disconnects: AtomicU64,
    /// In-flight responses abandoned because their connection died
    /// first. The server still completes them — the ledger stays exact.
    pub abandoned_inflight: AtomicU64,
    /// Replies flushed to peers *after* drain began (graceful-shutdown
    /// work that would have been lost by an abrupt close).
    pub drained_replies: AtomicU64,
    /// Connections poisoned by undecodable bytes.
    pub protocol_errors: AtomicU64,
    /// Accept-loop errors (including injected `net/accept` faults).
    pub accept_errors: AtomicU64,
}

impl NetMetrics {
    /// Fresh all-zero counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// One-line human-readable summary of every nonzero counter group.
    pub fn report(&self) -> String {
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let mut out = format!(
            "net: accepted={} closed={} frames_in={} frames_out={}",
            ld(&self.accepted),
            ld(&self.closed),
            ld(&self.frames_in),
            ld(&self.frames_out),
        );
        let tail = [
            ("busy", ld(&self.busy_replies)),
            ("tenant_busy", ld(&self.tenant_busy)),
            ("read_timeouts", ld(&self.read_timeouts)),
            ("write_timeouts", ld(&self.write_timeouts)),
            ("disconnects", ld(&self.disconnects)),
            ("abandoned", ld(&self.abandoned_inflight)),
            ("drained_replies", ld(&self.drained_replies)),
            ("protocol_errors", ld(&self.protocol_errors)),
            ("accept_errors", ld(&self.accept_errors)),
        ];
        for (name, v) in tail {
            if v > 0 {
                out.push_str(&format!(" {name}={v}"));
            }
        }
        out
    }
}

/// Per-tenant in-flight accounting for edge admission. A mutex over a
/// small map is fine here: it is touched twice per request (admit /
/// release), not per queue operation, and contention is bounded by the
/// I/O thread count, not the connection count.
pub struct TenantTable {
    cap: usize,
    inflight: Mutex<HashMap<u32, u64>>,
}

impl TenantTable {
    /// A table admitting at most `cap` in-flight requests per tenant
    /// (0 = unlimited; the table then never takes its lock).
    pub fn new(cap: usize) -> Self {
        TenantTable {
            cap,
            inflight: Mutex::new(HashMap::new()),
        }
    }

    /// Try to admit one request for `tenant`. `false` means the tenant
    /// is at its cap — the caller answers `Busy` without submitting.
    /// Every `true` must be paired with exactly one
    /// [`TenantTable::release`].
    pub fn try_admit(&self, tenant: u32) -> bool {
        if self.cap == 0 {
            return true;
        }
        let mut g = self.inflight.lock().unwrap();
        let e = g.entry(tenant).or_insert(0);
        if *e >= self.cap as u64 {
            false
        } else {
            *e += 1;
            true
        }
    }

    /// Release one admitted request for `tenant` (response delivered,
    /// abandoned, or refused downstream).
    pub fn release(&self, tenant: u32) {
        if self.cap == 0 {
            return;
        }
        let mut g = self.inflight.lock().unwrap();
        if let Some(e) = g.get_mut(&tenant) {
            *e = e.saturating_sub(1);
            if *e == 0 {
                g.remove(&tenant);
            }
        }
    }

    /// Current in-flight count for `tenant` (diagnostics).
    pub fn inflight(&self, tenant: u32) -> u64 {
        self.inflight
            .lock()
            .unwrap()
            .get(&tenant)
            .copied()
            .unwrap_or(0)
    }
}

/// State shared by the accept loop and every connection across all I/O
/// threads.
pub struct NetShared {
    /// Front-end configuration.
    pub cfg: NetConfig,
    /// Edge admission table.
    pub tenants: TenantTable,
    /// Socket-side counters.
    pub metrics: NetMetrics,
    /// Set once by shutdown: the accept loop stops and every
    /// connection begins draining.
    pub stop: AtomicBool,
    /// Gauge: connections accepted but not yet closed.
    pub active_conns: AtomicU64,
}

impl NetShared {
    /// Build the shared state for `cfg`.
    pub fn new(cfg: NetConfig) -> Self {
        let tenants = TenantTable::new(cfg.tenant_max_inflight);
        NetShared {
            cfg,
            tenants,
            metrics: NetMetrics::new(),
            stop: AtomicBool::new(false),
            active_conns: AtomicU64::new(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_table_caps_and_releases() {
        let t = TenantTable::new(2);
        assert!(t.try_admit(7));
        assert!(t.try_admit(7));
        assert!(!t.try_admit(7), "tenant 7 at cap");
        assert!(t.try_admit(8), "other tenants unaffected");
        assert_eq!(t.inflight(7), 2);
        t.release(7);
        assert!(t.try_admit(7), "release frees a slot");
        t.release(7);
        t.release(7);
        assert_eq!(t.inflight(7), 0, "entry removed at zero");
    }

    #[test]
    fn tenant_table_zero_cap_is_unlimited() {
        let t = TenantTable::new(0);
        for _ in 0..1000 {
            assert!(t.try_admit(1));
        }
        t.release(1); // no-op, must not underflow or panic
        assert_eq!(t.inflight(1), 0, "unlimited table keeps no counts");
    }

    #[test]
    fn net_metrics_report_hides_zero_tails() {
        let m = NetMetrics::new();
        m.accepted.store(3, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("accepted=3"));
        assert!(!r.contains("disconnects"), "zero counters stay silent");
        m.disconnects.store(1, Ordering::Relaxed);
        assert!(m.report().contains("disconnects=1"));
    }

    #[test]
    fn default_config_is_sane() {
        let c = NetConfig::default();
        assert!(c.io_threads >= 1);
        assert!(c.poll_min <= c.poll_max);
        assert!(c.handoff_capacity > 0);
        assert_eq!(c.tenant_max_inflight, 0, "edge cap off by default");
    }
}
