//! Chaos suite: fault-injection tests for the serving stack
//! (DESIGN.md §11). Only built with `--features failpoints`; the
//! driving invariant throughout is *conservation* — every admitted
//! request resolves (served, engine-failed, or NACKed), zero strand,
//! whatever the injected faults do to the threads serving it.
//!
//! The fail-point registry is process-global, so every test serializes
//! on [`serial`] and resets the registry on entry and exit.

#![cfg(feature = "failpoints")]

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use cmpq::coordinator::batcher::BatchPolicy;
use cmpq::coordinator::request::InferError;
use cmpq::coordinator::server::{Server, ServerConfig, SubmitError};
use cmpq::coordinator::supervisor::SupervisorPolicy;
use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
use cmpq::util::failpoint as fp;
use cmpq::CmpQueue;

static GUARD: Mutex<()> = Mutex::new(());

/// Serialize tests (global fail-point registry) and start clean. A
/// poisoned lock just means an earlier test failed; the registry reset
/// below restores the invariant the guard protects.
fn serial() -> MutexGuard<'static, ()> {
    let g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    fp::reset();
    g
}

fn echo_factory() -> EngineFactory {
    Arc::new(|| {
        Ok(Box::new(EchoEngine {
            batch: 8,
            features: 2,
            outputs: 1,
            scale: 2.0,
        }) as Box<dyn InferenceEngine>)
    })
}

/// Queue-layer fail point: an injected allocation error makes `push`
/// fail deterministically (the bounded-pool failure path) and clears
/// when disarmed.
#[test]
fn pool_alloc_error_fails_push_and_recovers() {
    let _g = serial();
    // Construct first: the dummy node allocates through the same site.
    let q: CmpQueue<u64> = CmpQueue::new();
    fp::arm("pool/alloc", fp::FailAction::Error, 1.0);
    assert_eq!(q.push(7), Err(7), "every alloc injected to fail");
    let (hits, trips) = fp::counters("pool/alloc");
    assert!(hits >= 1 && trips >= 1, "site evaluated and fired");
    fp::disarm("pool/alloc");
    assert_eq!(q.push(7), Ok(()));
    assert_eq!(q.pop(), Some(7));
    fp::reset();
}

/// Router-layer fail point: an injected route error surfaces as
/// `SubmitError::Overloaded` (shed, never stranded) and service
/// resumes when disarmed.
#[test]
fn route_error_sheds_at_submit() {
    let _g = serial();
    let server = Server::start(
        ServerConfig {
            shards: 1,
            workers: 1,
            batch_policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            ..ServerConfig::default()
        },
        echo_factory(),
    );
    fp::arm("router/route", fp::FailAction::Error, 1.0);
    assert!(matches!(
        server.submit(vec![1.0, 1.0]),
        Err(SubmitError::Overloaded)
    ));
    assert_eq!(server.metrics().shed.load(Ordering::Relaxed), 1);
    fp::disarm("router/route");
    let slot = server.submit(vec![3.0, 3.0]).expect("admitted after disarm");
    let resp = slot.wait_timeout(Duration::from_secs(20)).expect("served");
    assert_eq!(resp.output, vec![6.0]);
    let report = server.shutdown();
    assert_eq!(
        report.metrics.submitted.load(Ordering::Relaxed),
        report.metrics.completed.load(Ordering::Relaxed),
        "conservation: the shed request was never submitted"
    );
    fp::reset();
}

/// The tentpole invariant: 10k submissions with workers panicking at
/// p≈0.01 all resolve — served or NACKed, nothing stranded, and
/// `submitted == completed` at shutdown.
#[test]
fn conservation_under_injected_worker_panics() {
    let _g = serial();
    fp::set_seed(42);
    fp::arm("worker/pre-infer", fp::FailAction::Panic, 0.01);
    let server = Arc::new(Server::start(
        ServerConfig {
            shards: 2,
            workers: 2,
            batch_policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            supervisor: SupervisorPolicy {
                max_restarts: 1_000_000,
                backoff_base: Duration::from_micros(100),
                ..SupervisorPolicy::default()
            },
            ..ServerConfig::default()
        },
        echo_factory(),
    ));
    const CLIENTS: usize = 2;
    const PER_CLIENT: u64 = 5_000;
    const WAVE: usize = 200; // pipeline submits so batches actually fill
    let clients: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let server = server.clone();
            std::thread::spawn(move || {
                let (mut ok, mut nacked) = (0u64, 0u64);
                let mut left = PER_CLIENT;
                while left > 0 {
                    let wave = (left as usize).min(WAVE);
                    let slots: Vec<_> = (0..wave)
                        .map(|_| server.submit(vec![1.0, 1.0]).expect("no admission limit"))
                        .collect();
                    for s in slots {
                        let resp = s
                            .wait_timeout(Duration::from_secs(60))
                            .expect("resolved, not stranded");
                        if resp.error.is_none() {
                            ok += 1;
                        } else {
                            assert_eq!(resp.error, Some(InferError::WorkerPanicked));
                            nacked += 1;
                        }
                    }
                    left -= wave as u64;
                }
                (ok, nacked)
            })
        })
        .collect();
    let (mut ok, mut nacked) = (0u64, 0u64);
    for c in clients {
        let (o, n) = c.join().expect("client panicked");
        ok += o;
        nacked += n;
    }
    fp::disarm_all();
    let total = CLIENTS as u64 * PER_CLIENT;
    assert_eq!(ok + nacked, total, "every request resolved");
    let report = server_shutdown(server);
    let m = &report.metrics;
    assert_eq!(m.submitted.load(Ordering::Relaxed), total);
    assert_eq!(
        m.completed.load(Ordering::Relaxed),
        total,
        "conservation under chaos"
    );
    assert_eq!(m.nacks.load(Ordering::Relaxed), nacked);
    assert!(
        m.worker_panics.load(Ordering::Relaxed) >= 1,
        "p=0.01 over ~{} batches must fire",
        total / 8
    );
    assert_eq!(
        report.workers_dead, 0,
        "restart budget is effectively unlimited"
    );
    assert!(!report.degraded);
    fp::reset();
}

/// Exhausting the restart cap marks the worker dead, latches degraded
/// mode (visible through metrics), and shutdown still resolves every
/// outstanding request via the residual drain.
#[test]
fn restart_cap_exhaustion_degrades_and_drains() {
    let _g = serial();
    fp::arm("worker/pre-infer", fp::FailAction::Panic, 1.0);
    let server = Server::start(
        ServerConfig {
            shards: 1,
            workers: 1,
            batch_policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(1),
            },
            supervisor: SupervisorPolicy {
                max_restarts: 1,
                backoff_base: Duration::from_micros(500),
                ..SupervisorPolicy::default()
            },
            ..ServerConfig::default()
        },
        echo_factory(),
    );
    let mut slots = Vec::new();
    // Two spaced waves guarantee the worker claims at least two rounds:
    // panic → restart → panic → past the cap → dead.
    for wave in 0..2 {
        for _ in 0..8 {
            slots.push(server.submit(vec![1.0, 1.0]).expect("admitted"));
        }
        if wave == 0 {
            std::thread::sleep(Duration::from_millis(100));
        }
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.metrics().workers_dead.load(Ordering::Relaxed) == 0 {
        assert!(
            Instant::now() < deadline,
            "worker never hit the restart cap"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(server.is_degraded());
    let report = server.shutdown();
    assert_eq!(report.workers_dead, 1);
    assert!(report.degraded);
    assert!(!report.clean());
    for s in &slots {
        let resp = s.try_take().expect("resolved by NACK or shutdown drain");
        assert!(
            matches!(
                resp.error,
                Some(InferError::WorkerPanicked) | Some(InferError::ShuttingDown)
            ),
            "unexpected resolution: {:?}",
            resp.error
        );
    }
    let m = &report.metrics;
    assert_eq!(
        m.submitted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed),
        "conservation with a dead worker"
    );
    fp::reset();
}

/// A batcher crash-looped past its restart cap takes its shard out of
/// rotation and becomes a drain loop: requests submitted *after* the
/// batcher died still resolve with an explicit NACK instead of sitting
/// queued until shutdown (the no-hung-client invariant).
#[test]
fn dead_batcher_shard_nacks_instead_of_stranding() {
    let _g = serial();
    fp::arm("batcher/flush", fp::FailAction::Panic, 1.0);
    let server = Server::start(
        ServerConfig {
            shards: 1,
            workers: 1,
            batch_policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            supervisor: SupervisorPolicy {
                max_restarts: 1,
                backoff_base: Duration::from_micros(100),
                ..SupervisorPolicy::default()
            },
            ..ServerConfig::default()
        },
        echo_factory(),
    );
    // Feed the single batcher until its two flush panics exhaust the
    // restart cap (each submit triggers a 1ms-deadline flush).
    let mut slots = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(5);
    while server.metrics().batchers_dead.load(Ordering::Relaxed) == 0 {
        assert!(
            Instant::now() < deadline,
            "batcher never hit the restart cap"
        );
        slots.push(server.submit(vec![1.0, 1.0]).expect("admitted"));
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(server.is_degraded());
    fp::disarm_all();
    // The shard is dead — these can only resolve through the drain
    // loop, and they must do so long before any shutdown.
    for _ in 0..8 {
        slots.push(server.submit(vec![2.0, 2.0]).expect("admitted"));
    }
    for s in &slots {
        let resp = s
            .wait_timeout(Duration::from_secs(10))
            .expect("resolved, not stranded");
        assert_eq!(
            resp.error,
            Some(InferError::BatcherPanicked),
            "a dead shard answers with explicit NACKs"
        );
    }
    let report = server.shutdown();
    assert!(report.batchers_dead >= 1);
    assert!(report.degraded);
    let m = &report.metrics;
    assert_eq!(
        m.submitted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed),
        "conservation with a dead batcher"
    );
    fp::reset();
}

/// Engine that sleeps per batch, letting a single client outrun the
/// pipeline and hit the admission limit.
struct SlowEngine;

impl InferenceEngine for SlowEngine {
    fn batch_size(&self) -> usize {
        1
    }
    fn features_per_row(&self) -> usize {
        2
    }
    fn outputs_per_row(&self) -> usize {
        1
    }
    fn infer(&self, input: &[f32]) -> anyhow::Result<Vec<f32>> {
        std::thread::sleep(Duration::from_millis(5));
        Ok(vec![input[0]])
    }
}

/// Load shedding: above `max_inflight` the server refuses instead of
/// queueing without bound, and everything it *did* admit still resolves.
#[test]
fn shed_under_overload_conserves_admitted_requests() {
    let _g = serial();
    let factory: EngineFactory =
        Arc::new(|| Ok(Box::new(SlowEngine) as Box<dyn InferenceEngine>));
    let server = Server::start(
        ServerConfig {
            shards: 1,
            workers: 1,
            max_inflight: Some(4),
            batch_policy: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_micros(100),
            },
            ..ServerConfig::default()
        },
        factory,
    );
    const ATTEMPTS: usize = 50;
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for i in 0..ATTEMPTS {
        match server.submit(vec![i as f32, 0.0]) {
            Ok(slot) => admitted.push(slot),
            Err(SubmitError::Overloaded) => shed += 1,
        }
    }
    assert!(shed > 0, "a 5ms/batch engine cannot keep up with depth 4");
    for s in &admitted {
        assert!(
            s.wait_timeout(Duration::from_secs(30)).is_some(),
            "admitted requests all resolve"
        );
    }
    let report = server.shutdown();
    let m = &report.metrics;
    assert_eq!(m.shed.load(Ordering::Relaxed), shed);
    assert_eq!(m.submitted.load(Ordering::Relaxed), admitted.len() as u64);
    assert_eq!(
        m.submitted.load(Ordering::Relaxed) + shed,
        ATTEMPTS as u64,
        "every attempt accounted for exactly once"
    );
    assert_eq!(
        m.submitted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed),
        "conservation for the admitted subset"
    );
    fp::reset();
}

/// A wedged (not panicked) worker: an injected 1.5s stall stops its
/// heartbeat long enough for the monitor to flag it, and the gauge
/// clears once the worker resumes.
#[test]
fn stall_detection_flags_wedged_worker() {
    let _g = serial();
    fp::arm("worker/pre-infer", fp::FailAction::Delay(1_500_000), 1.0);
    let server = Server::start(
        ServerConfig {
            shards: 1,
            workers: 1,
            batch_policy: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_micros(100),
            },
            supervisor: SupervisorPolicy {
                // Well above the worker's 100ms idle-park slice (no
                // false positives) and well below the injected stall.
                stall_after: Duration::from_millis(300),
                monitor_period: Duration::from_millis(10),
                ..SupervisorPolicy::default()
            },
            ..ServerConfig::default()
        },
        echo_factory(),
    );
    let slot = server.submit(vec![1.0, 1.0]).expect("admitted");
    let deadline = Instant::now() + Duration::from_millis(1_300);
    while server.metrics().workers_stalled.load(Ordering::Relaxed) == 0 {
        assert!(
            Instant::now() < deadline,
            "monitor never flagged the wedged worker"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    fp::disarm_all();
    let resp = slot
        .wait_timeout(Duration::from_secs(20))
        .expect("served after the stall");
    assert!(resp.error.is_none(), "a stall is not a failure");
    let report = server.shutdown();
    assert_eq!(
        report.workers_dead, 0,
        "stalls do not consume the restart budget"
    );
    fp::reset();
}

/// Shutdown while a batcher delay is armed: the injected flush delay
/// slows the drain but every request still resolves before `shutdown`
/// returns.
#[test]
fn shutdown_completes_with_batcher_delays_armed() {
    let _g = serial();
    fp::set_seed(7);
    fp::arm("batcher/flush", fp::FailAction::Delay(2_000), 0.5);
    let server = Server::start(
        ServerConfig {
            shards: 2,
            workers: 2,
            batch_policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            ..ServerConfig::default()
        },
        echo_factory(),
    );
    let slots: Vec<_> = (0..64)
        .map(|i| server.submit(vec![i as f32, 0.0]).expect("admitted"))
        .collect();
    let report = server.shutdown();
    for s in &slots {
        assert!(s.try_take().is_some(), "resolved despite delayed flushes");
    }
    let m = &report.metrics;
    assert_eq!(m.submitted.load(Ordering::Relaxed), 64);
    assert_eq!(m.completed.load(Ordering::Relaxed), 64, "conservation");
    fp::reset();
}

/// Unwrap the last handle and shut down (chaos tests share clients).
fn server_shutdown(server: Arc<Server>) -> cmpq::coordinator::server::ShutdownReport {
    Arc::try_unwrap(server).ok().expect("all clients joined").shutdown()
}
