//! Wait-path integration tests (DESIGN.md §8): lost-wakeup stress with
//! pausing/resuming producers, `pop_deadline` timeout semantics across
//! implementations, blocking batch claims, and shutdown-while-parked
//! through the full serving pipeline.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cmpq::coordinator::server::{Server, ServerConfig};
use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
use cmpq::queue::{ConcurrentQueue, Impl};
use cmpq::CmpQueue;

fn echo_factory() -> EngineFactory {
    Arc::new(|| {
        Ok(Box::new(EchoEngine {
            batch: 4,
            features: 2,
            outputs: 1,
            scale: 1.0,
        }) as Box<dyn InferenceEngine>)
    })
}

#[test]
fn lost_wakeup_stress_with_pausing_producers() {
    // Producers pause and resume so consumers repeatedly drain the
    // queue, park, and must be woken by the next push. A lost wakeup
    // either hangs the receive loop (caught by the 30s budget) or
    // loses items (caught by the conservation check).
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
    let producers = 2usize;
    let consumers = 3usize;
    let per = 2_000u64;
    let total = producers as u64 * per;
    let received = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));

    let mut cons = Vec::new();
    for _ in 0..consumers {
        let q = q.clone();
        let received = received.clone();
        let stop = stop.clone();
        cons.push(std::thread::spawn(move || {
            let mut got = Vec::new();
            loop {
                match q.pop_deadline(Instant::now() + Duration::from_millis(100)) {
                    Some(v) => {
                        got.push(v);
                        received.fetch_add(1, Ordering::AcqRel);
                    }
                    None => {
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                    }
                }
            }
            got
        }));
    }
    let mut prods = Vec::new();
    for p in 0..producers {
        let q = q.clone();
        prods.push(std::thread::spawn(move || {
            let base = p as u64 * per;
            for i in 0..per {
                q.push(base + i).unwrap();
                // Pause often enough that consumers drain and park
                // between pushes — the window the epoch protocol must
                // cover.
                if i % 64 == 0 {
                    std::thread::sleep(Duration::from_micros(500));
                }
            }
        }));
    }
    for h in prods {
        h.join().unwrap();
    }
    let budget = Instant::now() + Duration::from_secs(30);
    while received.load(Ordering::Acquire) < total {
        assert!(
            Instant::now() < budget,
            "lost wakeup suspected: {}/{} received",
            received.load(Ordering::Acquire),
            total
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    stop.store(true, Ordering::Release);
    let mut all: Vec<u64> = Vec::new();
    for h in cons {
        all.extend(h.join().unwrap());
    }
    assert_eq!(all.len() as u64, total, "no loss");
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len() as u64, total, "no duplicates");
}

#[test]
fn pop_deadline_times_out_across_impls() {
    // CMP parks; baselines poll with bounded sleeps. Both must honor
    // the deadline on an empty queue — not return early, not oversleep.
    for imp in [Impl::Cmp, Impl::Mutex, Impl::Segmented] {
        let q: Arc<dyn ConcurrentQueue<u64>> = imp.make(64);
        let t0 = Instant::now();
        let r = q.pop_deadline(t0 + Duration::from_millis(60));
        let waited = t0.elapsed();
        assert_eq!(r, None, "{}", imp.name());
        assert!(
            waited >= Duration::from_millis(60),
            "{} returned early after {waited:?}",
            imp.name()
        );
        assert!(
            waited < Duration::from_secs(10),
            "{} overslept: {waited:?}",
            imp.name()
        );
    }
}

#[test]
fn deadline_pop_returns_item_pushed_while_parked() {
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
    let q2 = q.clone();
    let h = std::thread::spawn(move || {
        let t0 = Instant::now();
        let r = q2.pop_deadline(t0 + Duration::from_secs(20));
        (r, t0.elapsed())
    });
    std::thread::sleep(Duration::from_millis(30));
    q.push(42).unwrap();
    let (r, waited) = h.join().unwrap();
    assert_eq!(r, Some(42));
    assert!(
        waited < Duration::from_secs(10),
        "woken promptly, not at the deadline ({waited:?})"
    );
}

#[test]
fn pop_blocking_batch_claims_run_after_park() {
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
    let q2 = q.clone();
    let h = std::thread::spawn(move || {
        let mut out = Vec::new();
        let n = q2.pop_blocking_batch(16, &mut out);
        (n, out)
    });
    std::thread::sleep(Duration::from_millis(30));
    q.push_batch((0..8).collect::<Vec<_>>()).unwrap();
    let (n, out) = h.join().unwrap();
    assert!(n >= 1, "blocking batch claim woke and claimed");
    assert_eq!(out[0], 0, "FIFO preserved through the parked claim");
}

#[test]
fn shutdown_while_pipeline_parked() {
    // No traffic at all: batchers and workers escalate to parked within
    // a few ms. Shutdown must wake them and join promptly.
    let server = Server::start(
        ServerConfig {
            shards: 2,
            workers: 2,
            ..ServerConfig::default()
        },
        echo_factory(),
    );
    std::thread::sleep(Duration::from_millis(60));
    let t0 = Instant::now();
    let report = server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "shutdown hung on parked threads: {:?}",
        t0.elapsed()
    );
    assert!(report.clean());
    assert_eq!(report.metrics.completed.load(Ordering::Relaxed), 0);
}

#[test]
fn requests_complete_after_pipeline_parks() {
    // The pipeline idles (everyone parked), then a request arrives: the
    // push must wake the parked batcher, whose flush must wake the
    // parked worker — end to end through the eventcount layer.
    let server = Server::start(ServerConfig::default(), echo_factory());
    std::thread::sleep(Duration::from_millis(80));
    let out = server
        .infer_blocking(vec![2.0, 4.0], Duration::from_secs(20))
        .expect("response after idle park");
    assert_eq!(out, vec![3.0]); // mean of [2, 4] × scale 1
    server.shutdown();
}
