//! Model-checking the sharded fabric's cross-shard wakeup and steal
//! protocol (DESIGN.md §13) under the §9 schedule enumerator.
//!
//! The fabric's correctness argument has one load-bearing pivot: a
//! facade-level `parked` counter, incremented *before* a consumer's
//! post-registration re-sweep of **all** shards and read (after an SC
//! fence) by every producer after publishing. If the producer's read
//! misses the increment, the consumer's RMW is SC-after the read, so
//! the re-sweep must see the item; if the read sees it, the producer
//! notifies every shard. These tests enumerate that argument:
//!
//! * a protocol-level port (mini-shards as model atomics + the real
//!   `WaitStrategy` per shard) exhaustively explored at 1P×1C with the
//!   producer and consumer on *different* shards — the pure
//!   cross-shard case — and prefix-bounded at 2P×2C;
//! * detection-power variants: a consumer whose re-sweep covers only
//!   its home shard, and a producer that notifies only the shard it
//!   pushed — both must be caught as deadlocks and replay;
//! * the real `ShardedCmp` facade driven through `enqueue` /
//!   `pop_blocking`, and a steal-vs-reclaim accounting pass over
//!   `W = 1` shards.
#![cfg(feature = "model-check")]

use std::sync::atomic::Ordering::SeqCst;
use std::sync::Arc;
use std::sync::Mutex as StdMutex;

use cmpq::model::{
    explore_dfs, fuzz, replay, ExploreConfig, MAtomicU64, Outcome, Scenario, ThreadBody,
};
use cmpq::queue::cmp::{CmpConfig, ReclaimTrigger};
use cmpq::queue::sharded::{ShardMode, ShardedCmp, ShardedConfig};
use cmpq::queue::ConcurrentQueue;
use cmpq::util::WaitStrategy;

fn depth_from_env(default: usize) -> usize {
    std::env::var("MODEL_DEPTH")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
        .clamp(4, 9)
}

fn cfg_with_depth(depth: usize) -> ExploreConfig {
    ExploreConfig {
        max_depth: depth,
        max_steps: 10_000,
        max_executions: 600_000,
    }
}

// ---------------------------------------------------------------------
// Protocol-level port: 2 mini-shards, per-shard eventcounts, and the
// facade `parked` pivot, exactly as `ShardedCmp::pop_wait` orders them.
// ---------------------------------------------------------------------

const SHARDS: usize = 2;

struct FabricState {
    /// Item counter per mini-shard (the queue contents, abstracted).
    items: [MAtomicU64; SHARDS],
    /// Per-shard eventcount, as in the real fabric.
    ws: [WaitStrategy; SHARDS],
    /// The facade-level SC pivot.
    parked: MAtomicU64,
}

impl FabricState {
    fn new() -> Self {
        FabricState {
            items: [MAtomicU64::new(0), MAtomicU64::new(0)],
            ws: [WaitStrategy::new(), WaitStrategy::new()],
            parked: MAtomicU64::new(0),
        }
    }
}

fn try_take(st: &FabricState, shard: usize) -> bool {
    let mut cur = st.items[shard].load(SeqCst);
    while cur > 0 {
        match st.items[shard].compare_exchange(cur, cur - 1, SeqCst, SeqCst) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
    false
}

/// Home-first sweep over every shard (the steal scan).
fn sweep(st: &FabricState, home: usize) -> bool {
    (0..SHARDS).any(|k| try_take(st, (home + k) % SHARDS))
}

/// `ShardedCmp::pop_wait`'s ordering: sweep → register on the home
/// shard → announce on the pivot → re-sweep ALL shards → sleep.
fn consume_one(st: &FabricState, home: usize) {
    loop {
        if sweep(st, home) {
            return;
        }
        let registration = st.ws[home].registration();
        st.parked.fetch_add(1, SeqCst);
        if sweep(st, home) {
            st.parked.fetch_sub(1, SeqCst);
            return; // registration drops → cancel
        }
        registration.wait();
        st.parked.fetch_sub(1, SeqCst);
    }
}

/// Producer half: publish, then read the pivot (model atomics are SC,
/// so the load is the fence+load of the real `notify_waiters`) and
/// notify every shard's eventcount when anyone is inside the window.
fn produce_one(st: &FabricState, shard: usize) {
    st.items[shard].fetch_add(1, SeqCst);
    if st.parked.load(SeqCst) > 0 {
        for ws in &st.ws {
            ws.notify_if_waiting();
        }
    }
}

/// `producers[i]` pushes one item to the given shard; `homes[j]` is
/// consumer `j`'s affinity. Totals are balanced, so any surviving
/// sleeper is a lost cross-shard wakeup.
fn fabric_scenario(producers: Vec<usize>, homes: Vec<usize>) -> Scenario {
    assert_eq!(producers.len(), homes.len(), "one item per consumer");
    let st = Arc::new(FabricState::new());
    let mut threads: Vec<ThreadBody> = Vec::new();
    for shard in producers {
        let st = st.clone();
        threads.push(Box::new(move || produce_one(&st, shard)));
    }
    for home in homes {
        let st = st.clone();
        threads.push(Box::new(move || consume_one(&st, home)));
    }
    let st2 = st.clone();
    Scenario {
        threads,
        check: Box::new(move || {
            for (i, items) in st2.items.iter().enumerate() {
                if items.load(SeqCst) != 0 {
                    return Err(format!("shard {i} left {} item(s)", items.load(SeqCst)));
                }
            }
            if st2.parked.load(SeqCst) != 0 {
                return Err(format!("pivot stuck at {}", st2.parked.load(SeqCst)));
            }
            for (i, ws) in st2.ws.iter().enumerate() {
                if ws.waiters() != 0 {
                    return Err(format!("shard {i} leaked {} waiter(s)", ws.waiters()));
                }
            }
            Ok(())
        }),
    }
}

/// The pure cross-shard case — producer on shard 0, consumer homed on
/// shard 1 — fully enumerated. The home shard's eventcount never sees
/// a push-side notify unless the pivot read observes the park, so this
/// is exactly the lost-wakeup window the pivot closes.
#[test]
fn cross_shard_1p1c_full_exhaustive() {
    let report = explore_dfs(|| fabric_scenario(vec![0], vec![1]), cfg_with_depth(100_000));
    eprintln!(
        "cross-shard 1P1C: executions={} max_steps={} truncated={}",
        report.executions, report.max_steps_seen, report.depth_truncated
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
    assert!(!report.depth_truncated, "depth bound must never bind here");
    assert!(report.complete, "1P1C cross-shard race must be fully enumerable");
}

/// 2 producers (one per shard) × 2 consumers (affinity 0 and 1):
/// exhaustive over all schedule prefixes at the configured bound, then
/// deeper states via fixed-seed fuzz. Covers steal-vs-home claims,
/// double parks, and every pivot interleaving the bound reaches.
#[test]
fn affinity_and_steal_2x2_exhaustive_at_bound() {
    let depth = depth_from_env(6);
    let report = explore_dfs(|| fabric_scenario(vec![0, 1], vec![0, 1]), cfg_with_depth(depth));
    eprintln!(
        "2P2C sharded depth={depth}: executions={} max_steps={} truncated={}",
        report.executions, report.max_steps_seen, report.depth_truncated
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
    assert!(report.complete, "prefix space at depth {depth} must be exhausted");

    let fz = fuzz(
        || fabric_scenario(vec![0, 0], vec![0, 1]),
        cfg_with_depth(0),
        0x5AD,
        300,
    );
    assert!(fz.counterexample.is_none(), "fuzz: {:?}", fz.counterexample);
}

/// Detection power #1 — "steal without re-poll": the consumer's
/// post-registration re-sweep covers only its home shard. When the
/// producer's pivot read misses the park announcement, the item on the
/// *other* shard is never re-polled and the consumer sleeps forever.
/// The checker must exhibit the deadlock, and the schedule must replay.
#[test]
fn home_only_repoll_variant_is_caught() {
    fn broken_consume_one(st: &FabricState, home: usize) {
        loop {
            if sweep(st, home) {
                return;
            }
            let registration = st.ws[home].registration();
            st.parked.fetch_add(1, SeqCst);
            // BUG under test: re-polls the home shard only — a stolen
            // shard's item published concurrently is missed.
            if try_take(st, home) {
                st.parked.fetch_sub(1, SeqCst);
                return;
            }
            registration.wait();
            st.parked.fetch_sub(1, SeqCst);
        }
    }
    let factory = || {
        let st = Arc::new(FabricState::new());
        let p = st.clone();
        let c = st.clone();
        let threads: Vec<ThreadBody> = vec![
            Box::new(move || produce_one(&p, 0)),
            Box::new(move || broken_consume_one(&c, 1)),
        ];
        Scenario {
            threads,
            check: Box::new(|| Ok(())),
        }
    };
    let report = explore_dfs(factory, cfg_with_depth(12));
    let cx = report
        .counterexample
        .expect("the checker must find the missed cross-shard item");
    assert!(
        matches!(cx.outcome, Outcome::Deadlock { .. }),
        "expected a stranded consumer, got {cx:?}"
    );
    eprintln!(
        "home-only re-poll counterexample after {} executions: schedule {:?}",
        report.executions, cx.schedule
    );
    let again = replay(factory, &cx.schedule, 10_000);
    assert_eq!(again.outcome, cx.outcome, "counterexample must replay");
}

/// Detection power #2 — "notify the pushed shard only": the producer
/// skips the fan-out and wakes just the shard it published to. A
/// consumer parked on the *other* home never hears about it.
#[test]
fn single_shard_notify_variant_is_caught() {
    fn broken_produce_one(st: &FabricState, shard: usize) {
        st.items[shard].fetch_add(1, SeqCst);
        if st.parked.load(SeqCst) > 0 {
            // BUG under test: only the pushed shard's eventcount.
            st.ws[shard].notify_if_waiting();
        }
    }
    let factory = || {
        let st = Arc::new(FabricState::new());
        let p = st.clone();
        let c = st.clone();
        let threads: Vec<ThreadBody> = vec![
            Box::new(move || broken_produce_one(&p, 0)),
            Box::new(move || consume_one(&c, 1)),
        ];
        Scenario {
            threads,
            check: Box::new(|| Ok(())),
        }
    };
    let report = explore_dfs(factory, cfg_with_depth(12));
    let cx = report
        .counterexample
        .expect("the checker must find the unwoken cross-shard park");
    assert!(
        matches!(cx.outcome, Outcome::Deadlock { .. }),
        "expected a stranded consumer, got {cx:?}"
    );
    let again = replay(factory, &cx.schedule, 10_000);
    assert_eq!(again.outcome, cx.outcome, "counterexample must replay");
}

// ---------------------------------------------------------------------
// The real facade under the model.
// ---------------------------------------------------------------------

fn model_shard_cfg() -> CmpConfig {
    CmpConfig::default()
        .with_trigger(ReclaimTrigger::Manual)
        .without_magazines()
        .without_stats()
}

/// `enqueue` vs `pop_blocking` through the real `ShardedCmp` (route
/// ticket, shard push, pivot announce, home-shard park, full-fabric
/// re-sweep): prefix-bounded exhaustive + deep fuzz, no deadlock, no
/// lost item, pivot restored.
fn facade_park_scenario() -> Scenario {
    let q: Arc<ShardedCmp<u64>> = Arc::new(ShardedCmp::with_config(
        ShardedConfig::default()
            .with_shards(2)
            .with_mode(ShardMode::Relaxed { max_rank_error: 8 })
            .with_shard_config(model_shard_cfg()),
    ));
    let qp = q.clone();
    let qc = q.clone();
    let threads: Vec<ThreadBody> = vec![
        Box::new(move || {
            qp.enqueue(7);
        }),
        Box::new(move || {
            assert_eq!(qc.pop_blocking(), 7, "single item must arrive");
        }),
    ];
    let q2 = q.clone();
    Scenario {
        threads,
        check: Box::new(move || {
            if q2.parked_consumers() != 0 {
                return Err(format!("pivot stuck at {}", q2.parked_consumers()));
            }
            if let Some(v) = q2.try_dequeue() {
                return Err(format!("item {v} left behind"));
            }
            Ok(())
        }),
    }
}

#[test]
fn facade_pop_blocking_never_strands() {
    let report = explore_dfs(facade_park_scenario, cfg_with_depth(6));
    eprintln!(
        "facade park DFS: executions={} max_steps={}",
        report.executions, report.max_steps_seen
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
    assert!(report.complete);
    let fz = fuzz(facade_park_scenario, cfg_with_depth(0), 0xFAB, 200);
    assert!(fz.counterexample.is_none(), "fuzz: {:?}", fz.counterexample);
}

/// Steal vs reclaim at the minimum window (`W = 1`): two consumers
/// sweep-steal over a preloaded 2-shard fabric while a reclaimer
/// drives both shards. Every preloaded item is delivered exactly once
/// or dropped by a shard's reclaimer — never duplicated, never
/// invented — and the popped + drained + dropped accounting closes.
fn steal_vs_reclaim_scenario() -> Scenario {
    let cfg = CmpConfig::default()
        .with_window(1)
        .with_min_batch(1)
        .with_trigger(ReclaimTrigger::Manual)
        .without_magazines();
    let q: Arc<ShardedCmp<u64>> = Arc::new(ShardedCmp::with_config(
        ShardedConfig::default()
            .with_shards(2)
            .with_mode(ShardMode::Relaxed { max_rank_error: 8 })
            .with_shard_config(cfg),
    ));
    const PRELOAD: u64 = 4;
    for i in 0..PRELOAD {
        // Controller-side: round-robin routing lands 2 items per shard.
        q.enqueue(i);
    }
    let got_a = Arc::new(StdMutex::new(Vec::new()));
    let got_b = Arc::new(StdMutex::new(Vec::new()));
    let (qa, qb, qr) = (q.clone(), q.clone(), q.clone());
    let (ga, gb) = (got_a.clone(), got_b.clone());
    let threads: Vec<ThreadBody> = vec![
        Box::new(move || {
            for _ in 0..2 {
                if let Some(v) = qa.try_dequeue() {
                    ga.lock().unwrap().push(v);
                }
            }
        }),
        Box::new(move || {
            for _ in 0..2 {
                if let Some(v) = qb.try_dequeue() {
                    gb.lock().unwrap().push(v);
                }
            }
        }),
        Box::new(move || {
            for i in 0..2 {
                qr.shard(i).reclaim();
            }
        }),
    ];
    Scenario {
        threads,
        check: Box::new(move || {
            let a = got_a.lock().unwrap().clone();
            let b = got_b.lock().unwrap().clone();
            let mut all: Vec<u64> = a.iter().chain(b.iter()).copied().collect();
            all.sort_unstable();
            let popped = all.len() as u64;
            all.dedup();
            if all.len() as u64 != popped {
                return Err(format!("duplicate delivery: {a:?} {b:?}"));
            }
            if all.iter().any(|&v| v >= PRELOAD) {
                return Err(format!("phantom value: {all:?}"));
            }
            let mut drained = 0u64;
            while q.try_dequeue().is_some() {
                drained += 1;
            }
            let dropped: u64 = (0..2).map(|i| q.shard(i).stats().payloads_reclaimed).sum();
            if popped + drained + dropped != PRELOAD {
                return Err(format!(
                    "accounting broken: popped={popped} drained={drained} dropped={dropped}"
                ));
            }
            Ok(())
        }),
    }
}

#[test]
fn steal_vs_reclaim_accounting_holds() {
    let report = explore_dfs(steal_vs_reclaim_scenario, cfg_with_depth(6));
    eprintln!(
        "steal/reclaim DFS: executions={} max_steps={}",
        report.executions, report.max_steps_seen
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
    assert!(report.complete);
    let fz = fuzz(steal_vs_reclaim_scenario, cfg_with_depth(0), 0x57EA1, 300);
    assert!(fz.counterexample.is_none(), "fuzz: {:?}", fz.counterexample);
}
