//! Regression test for environment-variable fail-point arming.
//!
//! This lives in its own integration-test binary on purpose: the
//! registry's env parsing runs under a process-global `Once`, so the
//! scenario under test — the *first ever* registry touch happening
//! with `REPRO_FAILPOINTS` set — only exists while that `Once` is
//! still unfired. An earlier version deadlocked here: the `Once`
//! closure called `apply_spec` → `arm` → `init_from_env`, re-entering
//! `Once::call_once` on the same `Once`.
//!
//! The registry (unlike the sites) is always compiled, so this binary
//! needs no feature gate.

use cmpq::util::failpoint as fp;

#[test]
fn env_spec_arms_on_first_registry_touch() {
    // Single test in this binary → nothing can have fired the Once yet.
    std::env::set_var(fp::ENV_SEED, "42");
    std::env::set_var(
        fp::ENV_VAR,
        "test/env-armed=delay:1.0:7; test/env-off=off",
    );

    // First registry use: parses the env spec inside the Once closure.
    // With the reentrant-Once bug this call never returns.
    let armed = fp::check("test/env-armed");
    assert_eq!(armed, Some(fp::FailAction::Delay(7)), "env spec armed the site");
    assert_eq!(fp::check("test/env-off"), None, "off entries stay inert");

    let (hits, trips) = fp::counters("test/env-armed");
    assert!(hits >= 1 && trips >= 1, "env-armed site counted: {hits}/{trips}");
    let sites = fp::snapshot();
    assert!(
        sites.iter().any(|(name, armed, _, _)| name == "test/env-armed" && *armed),
        "snapshot sees the env-armed site"
    );
    fp::reset();
}
