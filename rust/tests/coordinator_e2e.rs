//! Coordinator end-to-end: the full serving pipeline over CMP queues,
//! with the echo engine (always) and the real AOT model (when
//! artifacts exist).

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use cmpq::coordinator::batcher::BatchPolicy;
use cmpq::coordinator::router::RoutePolicy;
use cmpq::coordinator::server::{Server, ServerConfig};
use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
use cmpq::runtime::ModelRuntime;

fn echo_factory(batch: usize, features: usize, outputs: usize) -> EngineFactory {
    Arc::new(move || {
        Ok(Box::new(EchoEngine {
            batch,
            features,
            outputs,
            scale: 3.0,
        }) as Box<dyn InferenceEngine>)
    })
}

#[test]
fn pipeline_under_concurrent_clients() {
    let server = Arc::new(Server::start(
        ServerConfig {
            shards: 2,
            workers: 2,
            route_policy: RoutePolicy::RoundRobin,
            batch_policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            ..ServerConfig::default()
        },
        echo_factory(4, 2, 1),
    ));
    let clients: Vec<_> = (0..6)
        .map(|c| {
            let server = server.clone();
            std::thread::spawn(move || {
                for i in 0..40u32 {
                    let v = (c * 100 + i) as f32;
                    let out = server
                        .submit(vec![v, v])
                        .expect("admitted")
                        .wait_timeout(Duration::from_secs(60))
                        .expect("response");
                    assert_eq!(out.output, vec![v * 3.0], "echo engine math");
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }
    let server = Arc::try_unwrap(server).ok().expect("clients joined");
    let report = server.shutdown();
    assert!(report.clean());
    let m = &report.metrics;
    assert_eq!(m.completed.load(Ordering::Relaxed), 240);
    assert_eq!(m.failures.load(Ordering::Relaxed), 0);
    let lat = m.latency_summary();
    assert!(lat.count == 240 && lat.p99_ns > 0);
}

#[test]
fn pipeline_routing_policies_all_complete() {
    for policy in [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastLoaded,
        RoutePolicy::HashId,
    ] {
        let server = Server::start(
            ServerConfig {
                shards: 3,
                workers: 1,
                route_policy: policy,
                batch_policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
            echo_factory(8, 1, 1),
        );
        let slots: Vec<_> = (0..60)
            .map(|i| server.submit(vec![i as f32]).expect("admitted"))
            .collect();
        for (i, s) in slots.iter().enumerate() {
            let out = s.wait_timeout(Duration::from_secs(60)).expect("response");
            assert_eq!(out.output, vec![i as f32 * 3.0], "{policy:?}");
        }
        let report = server.shutdown();
        assert_eq!(
            report.metrics.completed.load(Ordering::Relaxed),
            60,
            "{policy:?}"
        );
    }
}

#[test]
fn pipeline_with_real_model_when_artifacts_exist() {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature (stub runtime)");
        return;
    }
    let dir = std::env::var_os("CMPQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    if !dir.join("model.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let factory: EngineFactory = {
        let dir = dir.clone();
        Arc::new(move || {
            Ok(Box::new(ModelRuntime::load_from_artifacts(&dir)?) as Box<dyn InferenceEngine>)
        })
    };
    let server = Arc::new(Server::start(
        ServerConfig {
            shards: 2,
            workers: 1, // keep PJRT compile cost down in tests
            batch_policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
            },
            ..ServerConfig::default()
        },
        factory,
    ));
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let server = server.clone();
            std::thread::spawn(move || {
                for i in 0..8u32 {
                    let features: Vec<f32> =
                        (0..128).map(|k| ((c * 31 + i + k) as f32 * 0.01).sin()).collect();
                    let out = server
                        .submit(features)
                        .expect("admitted")
                        .wait_timeout(Duration::from_secs(120))
                        .expect("model response");
                    assert_eq!(out.output.len(), 16, "one logit row");
                    assert!(out.output.iter().all(|x| x.is_finite()));
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }
    let server = Arc::try_unwrap(server).ok().expect("clients joined");
    let m = server.shutdown().metrics;
    assert_eq!(m.completed.load(Ordering::Relaxed), 32);
    assert_eq!(m.failures.load(Ordering::Relaxed), 0);
}
