//! Rank-error / steal correctness oracle for the sharded CMP fabric
//! (DESIGN.md §13).
//!
//! Every enqueue is stamped with a global ticket drawn under a lock
//! (`serialize_stamps = true` in
//! [`cmpq::bench::workload::rank_error_trial`]), so the ticket order
//! *is* the true enqueue order and the replayed dequeue history can be
//! scored exactly:
//!
//! * **Strict** mode must score a rank error of exactly zero — the
//!   head-shard ordering ticket makes the fabric a single strict FIFO,
//!   no matter how many shards or stealing consumers are involved.
//! * **Relaxed** mode must keep the measured p99 under the
//!   `max_rank_error` the fabric was configured with.
//!
//! Both are swept across 1/2/8 shards × 1–8 consumers, plus a steal
//! storm (strict mode parks all items on shard 0 while consumers home
//! on the other shards) checked for exactly-once delivery.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use cmpq::bench::workload::{rank_error_trial, PairConfig, RankErrorStats};
use cmpq::queue::ConcurrentQueue;
use cmpq::{ShardMode, ShardedCmp, ShardedConfig};

const SHARD_COUNTS: [usize; 3] = [1, 2, 8];
const CONSUMER_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn fabric(shards: usize, mode: ShardMode) -> Arc<dyn ConcurrentQueue<u64>> {
    Arc::new(ShardedCmp::with_config(
        ShardedConfig::default().with_shards(shards).with_mode(mode),
    ))
}

#[test]
fn strict_rank_error_is_exactly_zero_across_combos() {
    for shards in SHARD_COUNTS {
        for consumers in CONSUMER_COUNTS {
            let pair = PairConfig {
                producers: 2,
                consumers,
            };
            let ops = 4_000;
            let trial = rank_error_trial(fabric(shards, ShardMode::Strict), pair, ops, true);
            assert_eq!(
                trial.items, ops,
                "conservation broken at {shards} shards × {}",
                pair.label()
            );
            assert_eq!(
                trial.stats,
                RankErrorStats::zero(),
                "strict fabric reordered at {shards} shards × {}: {:?}",
                pair.label(),
                trial.stats
            );
        }
    }
}

#[test]
fn relaxed_rank_error_p99_within_configured_bound() {
    const BOUND: u64 = 4096;
    for shards in SHARD_COUNTS {
        for consumers in CONSUMER_COUNTS {
            let pair = PairConfig {
                producers: 2,
                consumers,
            };
            let ops = 8_000;
            let q = fabric(shards, ShardMode::Relaxed { max_rank_error: BOUND });
            let trial = rank_error_trial(q, pair, ops, true);
            assert_eq!(
                trial.items, ops,
                "conservation broken at {shards} shards × {}",
                pair.label()
            );
            assert!(
                trial.stats.p99 <= BOUND,
                "relaxed p99 {} exceeds configured bound {BOUND} at {shards} shards × {} \
                 (p50={} max={})",
                trial.stats.p99,
                pair.label(),
                trial.stats.p50,
                trial.stats.max
            );
        }
    }
}

#[test]
fn relaxed_bound_is_exposed_on_the_handle() {
    let q = ShardedCmp::<u64>::with_config(
        ShardedConfig::default()
            .with_shards(4)
            .with_mode(ShardMode::Relaxed { max_rank_error: 64 }),
    );
    assert_eq!(q.mode().max_rank_error(), Some(64));
    assert!(!q.is_strict_fifo());
    let strict = ShardedCmp::<u64>::new(4);
    assert_eq!(strict.mode().max_rank_error(), None);
    assert!(strict.is_strict_fifo());
}

/// Steal storm: strict mode routes *every* push to shard 0, so of 8
/// consumers at most one has home shard 0 — deliveries to the rest can
/// only happen by stealing. Each payload carries its identity; a
/// per-payload delivery counter proves exactly-once end to end.
#[test]
fn steal_storm_delivers_exactly_once() {
    const TOTAL: u64 = 30_000;
    const PRODUCERS: usize = 2;
    const CONSUMERS: usize = 8;
    let q: Arc<ShardedCmp<u64>> = Arc::new(ShardedCmp::new(8));
    let delivered: Arc<Vec<AtomicU32>> =
        Arc::new((0..TOTAL).map(|_| AtomicU32::new(0)).collect());
    let next = Arc::new(std::sync::atomic::AtomicU64::new(0));
    let done = Arc::new(std::sync::atomic::AtomicU64::new(0));

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|_| {
            let q = Arc::clone(&q);
            let next = Arc::clone(&next);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                loop {
                    let t = next.fetch_add(1, Ordering::Relaxed);
                    if t >= TOTAL {
                        break;
                    }
                    q.enqueue(t);
                }
                done.fetch_add(1, Ordering::Release);
            })
        })
        .collect();
    let consumers: Vec<_> = (0..CONSUMERS)
        .map(|_| {
            let q = Arc::clone(&q);
            let delivered = Arc::clone(&delivered);
            let done = Arc::clone(&done);
            std::thread::spawn(move || loop {
                let deadline =
                    std::time::Instant::now() + std::time::Duration::from_millis(10);
                match q.pop_deadline(deadline) {
                    Some(v) => {
                        delivered[v as usize].fetch_add(1, Ordering::Relaxed);
                    }
                    None => {
                        if done.load(Ordering::Acquire) == PRODUCERS as u64 {
                            // All enqueues happen-before this read
                            // (Release/Acquire on `done`), but the empty
                            // sweep above may predate the last publish —
                            // one final drain closes that window.
                            while let Some(v) = q.try_dequeue() {
                                delivered[v as usize].fetch_add(1, Ordering::Relaxed);
                            }
                            return;
                        }
                    }
                }
            })
        })
        .collect();
    for h in producers {
        h.join().unwrap();
    }
    for h in consumers {
        h.join().unwrap();
    }
    for (i, c) in delivered.iter().enumerate() {
        assert_eq!(
            c.load(Ordering::Relaxed),
            1,
            "payload {i} delivered {} times",
            c.load(Ordering::Relaxed)
        );
    }
    assert_eq!(q.parked_consumers(), 0, "no consumer left parked");
}
