//! FIFO ordering guarantees (§3.7):
//!
//! * strict-FIFO queues: a single consumer must observe the exact
//!   global link order; with a single producer, every consumer's local
//!   sequence must be strictly increasing (real-time ordered dequeues
//!   from one thread can never invert a strict-FIFO queue).
//! * the segmented (moodycamel-style) comparator: only per-producer
//!   order — and we *demonstrate* that inter-producer interleaving is
//!   permitted (the trade-off the paper calls out in §2.3.2).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use cmpq::queue::{ConcurrentQueue, Impl};

/// Multi-producer, single-consumer: per-producer subsequences must be
/// in order for every queue; for strict-FIFO queues the merged order
/// must also respect each producer's enqueue order exactly.
fn per_producer_order(imp: Impl, producers: usize, per: u64) {
    let q: Arc<dyn ConcurrentQueue<(u8, u64)>> = imp.make(1 << 15);
    let handles: Vec<_> = (0..producers as u8)
        .map(|p| {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..per {
                    q.enqueue((p, i));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let mut last = vec![-1i64; producers];
    let mut count = 0u64;
    while let Some((p, i)) = q.try_dequeue() {
        assert!(
            last[p as usize] < i as i64,
            "{}: producer {p} inverted ({} then {})",
            imp.name(),
            last[p as usize],
            i
        );
        last[p as usize] = i as i64;
        count += 1;
    }
    assert_eq!(count, producers as u64 * per, "{}", imp.name());
}

#[test]
fn per_producer_order_all_impls() {
    for imp in Impl::ALL {
        per_producer_order(imp, 3, 3_000);
    }
}

/// Single producer, multiple consumers, strict-FIFO queues: each
/// consumer's received values must be strictly increasing.
fn consumer_monotonicity(imp: Impl) {
    let q: Arc<dyn ConcurrentQueue<u64>> = imp.make(1 << 15);
    let total = 30_000u64;
    let done = Arc::new(AtomicBool::new(false));
    let producer = {
        let q = q.clone();
        std::thread::spawn(move || {
            for i in 0..total {
                q.enqueue(i);
            }
        })
    };
    let consumers: Vec<_> = (0..4)
        .map(|_| {
            let q = q.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match q.try_dequeue() {
                        Some(v) => got.push(v),
                        None => {
                            if done.load(Ordering::Acquire) && q.try_dequeue().is_none() {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                got
            })
        })
        .collect();
    producer.join().unwrap();
    done.store(true, Ordering::Release);
    let mut union = Vec::new();
    for h in consumers {
        let got = h.join().unwrap();
        for w in got.windows(2) {
            assert!(
                w[0] < w[1],
                "{}: consumer saw {} before {} — FIFO violated",
                imp.name(),
                w[0],
                w[1]
            );
        }
        union.extend(got);
    }
    union.sort_unstable();
    assert_eq!(union, (0..total).collect::<Vec<_>>());
}

#[test]
fn strict_fifo_consumer_monotonicity_cmp() {
    consumer_monotonicity(Impl::Cmp);
}

#[test]
fn strict_fifo_consumer_monotonicity_ms_hp() {
    consumer_monotonicity(Impl::MsHp);
}

#[test]
fn strict_fifo_consumer_monotonicity_ms_ebr() {
    consumer_monotonicity(Impl::MsEbr);
}

#[test]
fn strict_fifo_consumer_monotonicity_ms_helping() {
    consumer_monotonicity(Impl::MsHelping);
}

#[test]
fn strict_fifo_consumer_monotonicity_vyukov() {
    consumer_monotonicity(Impl::Vyukov);
}

/// Single producer + single consumer: exact global order, all impls.
#[test]
fn spsc_exact_order_all_impls() {
    for imp in Impl::ALL {
        let q: Arc<dyn ConcurrentQueue<u64>> = imp.make(1 << 15);
        let total = 20_000u64;
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..total {
                    q.enqueue(i);
                }
            })
        };
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut expect = 0u64;
                while expect < total {
                    if let Some(v) = q.try_dequeue() {
                        assert_eq!(v, expect, "{}: out of order", imp.name());
                        expect += 1;
                    } else {
                        std::thread::yield_now();
                    }
                }
            })
        };
        producer.join().unwrap();
        consumer.join().unwrap();
    }
}

/// The segmented comparator *documents* its relaxation: with two
/// producers, a single consumer can observe inter-producer interleaving
/// that strict global FIFO would forbid. We assert the queue delivers
/// everything and preserves per-producer order — and that the paper's
/// strict-FIFO test (enqueue-time global stamps come out sorted) is
/// *not* guaranteed, by checking CMP passes it on the same schedule.
#[test]
fn segmented_relaxation_vs_cmp_strictness() {
    use std::sync::atomic::AtomicU64;
    // Global stamp assigned at enqueue call time. For CMP the dequeue
    // order must match stamp order when a single thread both stamps and
    // enqueues atomically (single producer); run single-producer here
    // so the property is exact, then two-producer to compare shapes.
    let stamps = Arc::new(AtomicU64::new(0));
    let cmp: Arc<dyn ConcurrentQueue<u64>> = Impl::Cmp.make(0);
    for _ in 0..1000 {
        cmp.enqueue(stamps.fetch_add(1, Ordering::Relaxed));
    }
    let mut prev = None;
    while let Some(v) = cmp.try_dequeue() {
        if let Some(p) = prev {
            assert!(v > p, "CMP strict order");
        }
        prev = Some(v);
    }
    // Segmented with 2 producers: everything arrives, per-producer
    // ordered (already covered), but global interleaving is free-form —
    // nothing to assert beyond conservation, which IS the difference.
    let seg: Arc<dyn ConcurrentQueue<(u8, u64)>> = Impl::Segmented.make(0);
    let handles: Vec<_> = (0..2u8)
        .map(|p| {
            let q = seg.clone();
            std::thread::spawn(move || {
                for i in 0..2000 {
                    q.enqueue((p, i));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let mut n = 0;
    while seg.try_dequeue().is_some() {
        n += 1;
    }
    assert_eq!(n, 4000);
}
