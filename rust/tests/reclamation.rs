//! Bounded reclamation (§3.6): CMP's memory footprint must stay
//! bounded by live items + W + batch slack under sustained concurrent
//! churn — unlike coordination-based schemes whose retention depends on
//! thread behavior (see fault_tolerance.rs).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use cmpq::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};

#[test]
fn footprint_bounded_under_concurrent_churn() {
    let window = 2048u64;
    let q = Arc::new(CmpQueue::<u64>::with_config(
        CmpConfig::default()
            .with_window(window)
            .with_reclaim_period(256)
            .with_min_batch(16),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let moved = Arc::new(AtomicU64::new(0));

    let workers: Vec<_> = (0..4)
        .map(|w| {
            let q = q.clone();
            let stop = stop.clone();
            let moved = moved.clone();
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Acquire) {
                    if w % 2 == 0 {
                        q.push(i).unwrap();
                        i += 1;
                    } else if q.pop().is_some() {
                        moved.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();

    std::thread::sleep(std::time::Duration::from_millis(600));
    stop.store(true, Ordering::Release);
    for h in workers {
        h.join().unwrap();
    }
    // Drain leftover AVAILABLE items so only window slack remains.
    while q.pop().is_some() {}
    q.reclaim();

    let churned = moved.load(Ordering::Relaxed) + q.footprint_nodes();
    // The real assertion: footprint ≪ total churn, bounded by queue
    // residue at stop time + W + slack (residue can be large if the
    // enqueuers outpaced dequeuers, so bound against in_use post-drain).
    let in_use = q.nodes_in_use();
    assert!(
        in_use <= window + 4096 + 1,
        "in_use={in_use} not bounded by W + slack (churned≈{churned})"
    );
    assert!(q.stats().nodes_reclaimed > 0, "reclamation actually ran");
}

#[test]
fn steady_state_footprint_independent_of_total_ops() {
    // 10x the work must NOT mean 10x the footprint (§3.1: memory is
    // bounded by window_size × node_size regardless of total volume).
    let run = |total: u64| -> u64 {
        let q = CmpQueue::<u64>::with_config(
            CmpConfig::default()
                .with_window(512)
                .with_reclaim_period(128)
                .with_min_batch(8),
        );
        for i in 0..total {
            q.push(i).unwrap();
            q.pop().unwrap();
        }
        q.footprint_nodes()
    };
    let small = run(20_000);
    let large = run(200_000);
    assert!(
        large <= small * 2,
        "footprint grew with volume: {small} -> {large}"
    );
}

#[test]
fn concurrent_reclaim_is_single_flight() {
    // Many threads calling reclaim() concurrently: exactly-once pass
    // semantics per window state, no corruption, contended calls skip.
    let q = Arc::new(CmpQueue::<u64>::with_config(
        CmpConfig::default()
            .with_window(64)
            .with_min_batch(1)
            .with_trigger(ReclaimTrigger::Manual),
    ));
    for i in 0..50_000 {
        q.push(i).unwrap();
    }
    for _ in 0..50_000 {
        q.pop().unwrap();
    }
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut freed = 0u64;
                for _ in 0..50 {
                    freed += q.reclaim();
                }
                freed
            })
        })
        .collect();
    let total_freed: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total_freed >= 50_000 - 65 - 8, "most nodes freed: {total_freed}");
    assert!(total_freed <= 50_000, "never over-free");
    // Note: on a single-core testbed concurrent reclaim() calls rarely
    // overlap, so `reclaim_contended` may legitimately be zero — the
    // single-flight property is already proven by `total_freed` never
    // exceeding the reclaimable count (no double-free over 400 passes).
    let s = q.stats();
    assert_eq!(s.nodes_reclaimed, total_freed);
}

#[test]
fn queue_usable_during_reclaim_storm() {
    // Operations proceed unimpeded while a dedicated thread hammers
    // reclaim() (§3.6: reclamation "allows normal queue operations to
    // proceed unimpeded").
    let q = Arc::new(CmpQueue::<u64>::with_config(
        CmpConfig::default()
            .with_window(128)
            .with_min_batch(1)
            .with_trigger(ReclaimTrigger::Manual),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let reclaimer = {
        let q = q.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                q.reclaim();
            }
        })
    };
    for i in 0..100_000u64 {
        q.push(i).unwrap();
        assert_eq!(q.pop(), Some(i), "FIFO intact during reclaim storm");
    }
    stop.store(true, Ordering::Release);
    reclaimer.join().unwrap();
    // Footprint is a high-water mark; on a 1-core testbed the main loop
    // can burst a full scheduler quantum (~tens of thousands of ops)
    // between reclaimer timeslices, so the bound is quantum-scale, not
    // window-scale. The hard requirements: ops stayed FIFO (asserted in
    // the loop), reclamation made real progress, and the footprint
    // stayed below the total churn (no unbounded growth).
    assert!(
        q.footprint_nodes() < 100_000,
        "footprint exceeded total churn: {}",
        q.footprint_nodes()
    );
    assert!(
        q.stats().nodes_reclaimed > 10_000,
        "reclaimer made real progress: {}",
        q.stats().nodes_reclaimed
    );
}

#[test]
fn window_zero_like_config_never_reclaims_tail_or_available() {
    // Adversarially small window: correctness must hold (the defensive
    // tail guard + AVAILABLE rule), even though ABA-window guarantees
    // are technically void at W=1.
    let q = CmpQueue::<u64>::with_config(
        CmpConfig::default()
            .with_window(1)
            .with_min_batch(1)
            .with_trigger(ReclaimTrigger::Modulo)
            .with_reclaim_period(2),
    );
    for round in 0..2000u64 {
        q.push(round * 2).unwrap();
        q.push(round * 2 + 1).unwrap();
        assert_eq!(q.pop(), Some(round * 2));
        assert_eq!(q.pop(), Some(round * 2 + 1));
    }
    assert_eq!(q.pop(), None);
}

#[test]
fn deque_cycle_monotonicity_under_concurrency() {
    let q = Arc::new(CmpQueue::<u64>::new());
    let stop = Arc::new(AtomicBool::new(false));
    let watcher = {
        let q = q.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut last = 0u64;
            let mut violations = 0;
            while !stop.load(Ordering::Acquire) {
                let now = q.dequeue_cycle();
                if now < last {
                    violations += 1;
                }
                last = now;
            }
            violations
        })
    };
    let workers: Vec<_> = (0..4)
        .map(|_| {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..20_000u64 {
                    q.push(i).unwrap();
                    q.pop();
                }
            })
        })
        .collect();
    for h in workers {
        h.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    assert_eq!(watcher.join().unwrap(), 0, "deque_cycle must be monotonic");
}
