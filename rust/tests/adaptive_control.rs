//! Adaptive-behavior test net for the §15 runtime control plane.
//!
//! Pins the control laws themselves (EWMA determinism, convergence
//! bounds, the spin-budget monotonicity that keeps faster arrivals
//! from ever drifting *toward* parking), the gap-tracker regime
//! changes on synthetic arrival traces, the live decisions a real
//! adaptive `CmpQueue` publishes under idle vs burst load, and the
//! A/B guarantee the whole feature rides on: adaptive mode must never
//! be meaningfully worse than the fixed knobs it replaces.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cmpq::bench::workload::{run_throughput_on, PairConfig, Scenario, TrialConfig};
use cmpq::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};
use cmpq::queue::{ConcurrentQueue, Impl};
use cmpq::runtime::adaptive::{
    flush_wait_for, reclaim_p_for, spin_budget_for, Ewma, GapTracker, QueueAdaptive, FULL_SPIN_GAP_NS,
    GAP_ALPHA, GAP_CAP_NS, MAX_SPIN_STEPS,
};
use cmpq::util::XorShift64;

// ---------------------------------------------------------------------
// Control-law properties (pure functions — fully deterministic).
// ---------------------------------------------------------------------

/// The same seeded trace must produce bit-identical EWMA trajectories:
/// the estimator has no hidden state, clocks, or allocation order to
/// diverge on.
#[test]
fn ewma_is_deterministic_for_a_seeded_trace() {
    let trace = |seed: u64| -> Vec<f64> {
        let mut rng = XorShift64::new(seed);
        let mut e = Ewma::new(GAP_ALPHA);
        (0..1000)
            .map(|_| e.observe(rng.next_f64() * 1e6))
            .collect()
    };
    let a = trace(42);
    let b = trace(42);
    assert_eq!(a, b, "identical seeds must replay identically");
    assert_ne!(a, trace(43), "different seeds must actually differ");
}

/// Step response: after the input jumps to a new constant, the error
/// decays geometrically as `(1 − α)^n` — the bound that sizes how many
/// arrivals a regime flip costs.
#[test]
fn ewma_converges_geometrically_under_a_step() {
    let mut e = Ewma::new(GAP_ALPHA);
    e.observe(1e6); // prime in the old regime
    let target = 1e3;
    let mut expected_err = 1e6 - target;
    for n in 1..=40 {
        let v = e.observe(target);
        expected_err *= 1.0 - GAP_ALPHA;
        let err = (v - target).abs();
        assert!(
            (err - expected_err).abs() < 1e-6,
            "step {n}: error {err} deviates from (1-α)^n bound {expected_err}"
        );
    }
    // A dozen arrivals get within 3% of the new regime.
    assert!((e.value().unwrap() - target) / (1e6 - target) < 0.03);
}

/// Burst immunity: a single outlier moves the estimate by at most
/// `α × (outlier − value)`, and a handful of tight follow-ups undo it.
#[test]
fn ewma_rides_out_single_outliers() {
    let mut e = Ewma::new(GAP_ALPHA);
    for _ in 0..20 {
        e.observe(1_000.0);
    }
    let before = e.value().unwrap();
    let after_outlier = e.observe(1e8);
    assert!(
        after_outlier <= before + GAP_ALPHA * (1e8 - before) + 1e-6,
        "one outlier is damped by α"
    );
    for _ in 0..20 {
        e.observe(1_000.0);
    }
    let recovered = e.value().unwrap();
    assert!(
        recovered < 1e8 * 0.01,
        "tight follow-ups must bury the outlier: {recovered}"
    );
}

/// The satellite monotonicity property: faster arrivals can never
/// shrink the spin budget (never push a consumer *toward* parking).
/// Checked both pointwise over random gap pairs and along whole
/// traces, where a uniformly faster trace keeps a uniformly
/// greater-or-equal budget at every step.
#[test]
fn faster_arrivals_never_shrink_the_spin_budget() {
    let mut rng = XorShift64::new(0xBEEF);
    for _ in 0..10_000 {
        let a = rng.next_below(GAP_CAP_NS);
        let b = rng.next_below(GAP_CAP_NS);
        let (fast, slow) = (a.min(b), a.max(b));
        assert!(
            spin_budget_for(fast) >= spin_budget_for(slow),
            "budget({fast}) < budget({slow})"
        );
    }
    // Trace form: the same arrival process sped up 4× (every gap
    // quartered). The EWMA is linear, so the fast trace's estimate is
    // exactly a quarter of the slow one at every step — and the budget
    // law must respect the ordering throughout.
    let mut rng = XorShift64::new(7);
    let mut slow = Ewma::new(GAP_ALPHA);
    let mut fast = Ewma::new(GAP_ALPHA);
    for _ in 0..2_000 {
        let gap = rng.next_below(GAP_CAP_NS) as f64;
        let s = slow.observe(gap);
        let f = fast.observe(gap / 4.0);
        assert!(
            spin_budget_for(f as u64) >= spin_budget_for(s as u64),
            "faster trace fell below the slower one: {f} vs {s}"
        );
    }
}

/// Endpoint pins for all three laws, so a refactor cannot silently
/// invert a slope (module unit tests cover the full monotone sweeps).
#[test]
fn control_law_endpoints() {
    assert_eq!(spin_budget_for(FULL_SPIN_GAP_NS), MAX_SPIN_STEPS);
    assert_eq!(spin_budget_for(GAP_CAP_NS), 0);
    let base = 1.0 / 1024.0;
    assert!(reclaim_p_for(base, 0.0) > base, "empty window: eager");
    assert!(reclaim_p_for(base, 1.0) < base, "full window: lazy");
    let w = Duration::from_millis(2);
    assert_eq!(flush_wait_for(w, 0.0), w, "starved batcher keeps max_wait");
    assert!(flush_wait_for(w, 1.0) < w, "full batcher flushes sooner");
}

// ---------------------------------------------------------------------
// GapTracker regimes over synthetic (constructed-Instant) traces.
// ---------------------------------------------------------------------

/// Burst → idle → burst on a synthetic clock: the tracker's smoothed
/// gap (and the derived budget) must follow each regime flip within a
/// bounded number of arrivals. No real clocks — every Instant is
/// constructed, so this is deterministic on any machine.
#[test]
fn gap_tracker_follows_burst_and_idle_regimes() {
    let mut t = GapTracker::new();
    let t0 = Instant::now();
    let mut now = t0;
    assert_eq!(t.observe(now), None, "first arrival has no gap");

    // Tight phase: 50 arrivals 1 µs apart → full spin budget.
    for _ in 0..50 {
        now += Duration::from_micros(1);
        t.observe(now);
    }
    let tight = t.gap_ewma_ns().unwrap();
    assert!(tight <= FULL_SPIN_GAP_NS, "tight regime: {tight} ns");
    assert_eq!(spin_budget_for(tight), MAX_SPIN_STEPS);

    // Idle phase: 30 arrivals 100 ms apart → immediate park.
    for _ in 0..30 {
        now += Duration::from_millis(100);
        t.observe(now);
    }
    let idle = t.gap_ewma_ns().unwrap();
    assert!(idle > 10_000_000, "idle regime must dominate: {idle} ns");
    assert_eq!(spin_budget_for(idle), 0);

    // Back to tight: convergence within ~a hundred arrivals, as the
    // (1-α)^n bound promises (0.75^100 × 100 ms ≪ 4 µs).
    for _ in 0..100 {
        now += Duration::from_micros(1);
        t.observe(now);
    }
    let back = t.gap_ewma_ns().unwrap();
    assert!(back <= FULL_SPIN_GAP_NS, "regime must flip back: {back} ns");
    assert_eq!(spin_budget_for(back), MAX_SPIN_STEPS);
}

/// Published decisions stay mutually consistent: whatever gap the
/// tracker hands to [`QueueAdaptive::record_gap`], the stored budget
/// is exactly the law applied to the stored gap.
#[test]
fn published_budget_always_matches_published_gap() {
    let qa = QueueAdaptive::new(1.0 / 512.0);
    let mut rng = XorShift64::new(0xA11CE);
    for _ in 0..1_000 {
        qa.record_gap(rng.next_below(GAP_CAP_NS * 2));
        let snap = qa.snapshot();
        assert_eq!(snap.spin_budget, spin_budget_for(snap.gap_ewma_ns));
    }
}

// ---------------------------------------------------------------------
// The real queue: decisions visibly move between idle and burst.
// ---------------------------------------------------------------------

fn adaptive_cfg() -> CmpConfig {
    CmpConfig::default()
        .with_trigger(ReclaimTrigger::Bernoulli)
        .with_adaptive()
}

/// Idle phase (arrivals milliseconds apart) must drive the learned
/// spin budget to an immediate park; a subsequent burst drain must
/// pull the smoothed gap back down. This is the live half of the
/// acceptance criterion ("gauges visibly move between bursty and idle
/// phases"), asserted directly on the queue's published snapshot.
#[test]
fn adaptive_queue_learns_idle_then_recovers_on_burst() {
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::with_config(adaptive_cfg()));
    assert_eq!(
        q.adaptive_snapshot().spin_budget,
        MAX_SPIN_STEPS,
        "unknown regime starts optimistic (fixed-schedule spinning)"
    );

    // Idle phase: 5 items spaced ~4 ms. The consumer's observed
    // inter-arrival gaps are all ≥ the spacing, so the EWMA lands well
    // past the 262 µs park threshold — deterministically budget 0.
    let qc = q.clone();
    let consumer = std::thread::spawn(move || {
        for i in 0..5u64 {
            assert_eq!(qc.pop_blocking(), i);
        }
    });
    for i in 0..5u64 {
        std::thread::sleep(Duration::from_millis(4));
        q.push(i).unwrap();
    }
    consumer.join().unwrap();
    let idle = q.adaptive_snapshot();
    assert!(
        idle.gap_ewma_ns >= 1_000_000,
        "ms-spaced arrivals must read as a wide gap: {} ns",
        idle.gap_ewma_ns
    );
    assert_eq!(idle.spin_budget, 0, "idle regime parks immediately");
    let stats = q.stats();
    assert!(stats.wait_parks > 0, "idle waits actually parked");

    // The control report exports the same story.
    let report = q.control_report().expect("cmp reports its control plane");
    let ratio = report.park_ratio.expect("stats on ⇒ park ratio known");
    assert!(ratio > 0.0 && ratio <= 1.0, "park ratio {ratio}");
    assert!(report.reclaim_p.is_some());
    assert_eq!(report.spin_budget, Some(0));

    // Burst phase: a prefilled queue drained through the blocking path
    // publishes hundreds of tight gaps; the smoothed gap must fall
    // (strictly below the idle estimate — robust to scheduler jitter,
    // which would have to exceed the idle spacing itself to mask it).
    for i in 0..300u64 {
        q.push(i).unwrap();
    }
    for i in 0..300u64 {
        assert_eq!(q.pop_blocking(), i);
    }
    let burst = q.adaptive_snapshot();
    assert!(
        burst.gap_ewma_ns < idle.gap_ewma_ns,
        "burst drain must pull the gap down: {} → {}",
        idle.gap_ewma_ns,
        burst.gap_ewma_ns
    );
    assert_eq!(
        burst.spin_budget,
        spin_budget_for(burst.gap_ewma_ns),
        "published decisions stay consistent"
    );
}

/// The `Impl` registry wires the adaptive variant correctly: same
/// element contract as plain CMP, distinct report name, adaptive
/// control plane armed.
#[test]
fn impl_registry_exposes_the_adaptive_variant() {
    let fixed: Arc<dyn ConcurrentQueue<u64>> = Impl::Cmp.make(1 << 10);
    let adaptive: Arc<dyn ConcurrentQueue<u64>> = Impl::CmpAdaptive.make(1 << 10);
    assert_eq!(fixed.name(), "cmp");
    assert_eq!(adaptive.name(), "cmp-adaptive");
    assert!(adaptive.is_strict_fifo() && adaptive.is_lock_free());
    for i in 0..100u64 {
        adaptive.enqueue(i);
    }
    for i in 0..100u64 {
        assert_eq!(adaptive.try_dequeue(), Some(i), "FIFO preserved");
    }
    // Fixed mode reports the configured constant; the registry's
    // adaptive queue reports a live probability too.
    let fr = fixed.control_report().unwrap();
    let ar = adaptive.control_report().unwrap();
    assert!(fr.reclaim_p.is_some() && ar.reclaim_p.is_some());
    // A mutex baseline has no control plane at all.
    let mx: Arc<dyn ConcurrentQueue<u64>> = Impl::Mutex.make(1 << 10);
    assert_eq!(mx.control_report(), None);
}

// ---------------------------------------------------------------------
// A/B smoke: adaptive must not lose to the fixed knobs it replaces.
// ---------------------------------------------------------------------

struct AbBest {
    items_per_sec: f64,
    ops_per_cpu_sec: f64,
}

/// Best-of-3 for one implementation under one trial shape. Best-of
/// (not mean) so a single descheduled round cannot fail the A/B
/// assertion; the two variants share every fast-path instruction, so
/// their bests track each other tightly.
fn best_of_3(imp: Impl, pair: PairConfig, cfg: &TrialConfig) -> AbBest {
    let mut best = AbBest {
        items_per_sec: 0.0,
        ops_per_cpu_sec: 0.0,
    };
    for _ in 0..3 {
        let t = run_throughput_on(imp.make(1 << 16), pair, cfg);
        best.items_per_sec = best.items_per_sec.max(t.items_per_sec);
        if let Some(c) = t.ops_per_cpu_sec {
            best.ops_per_cpu_sec = best.ops_per_cpu_sec.max(c);
        }
    }
    best
}

/// Closed loop: consumers never block, so the adaptive path is never
/// even sampled — throughput must be within the ±10% noise band of
/// fixed CMP (best-of-3 on both sides).
#[test]
fn adaptive_closed_loop_throughput_is_no_worse() {
    let cfg = TrialConfig {
        total_ops: 30_000,
        scenario: Scenario::ClosedLoop,
        ..TrialConfig::default()
    };
    let pair = PairConfig::symmetric(2);
    let fixed = best_of_3(Impl::Cmp, pair, &cfg);
    let adaptive = best_of_3(Impl::CmpAdaptive, pair, &cfg);
    assert!(
        adaptive.items_per_sec >= fixed.items_per_sec * 0.9,
        "adaptive closed-loop regressed: {} vs {} items/s",
        adaptive.items_per_sec,
        fixed.items_per_sec
    );
}

/// Bursty/idle alternation (the `adaptive_burst` workload shape):
/// consumers park between bursts, which is exactly where the learned
/// budget sheds spin work. CPU efficiency (items per CPU-second) must
/// be at least fixed CMP's, within the same 10% noise allowance.
#[test]
fn adaptive_bursty_cpu_efficiency_is_no_worse() {
    let cfg = TrialConfig {
        total_ops: 6_000,
        scenario: Scenario::Bursty {
            burst: 256,
            gap: Duration::from_millis(3),
        },
        ..TrialConfig::default()
    };
    let pair = PairConfig::symmetric(2);
    let fixed = best_of_3(Impl::Cmp, pair, &cfg);
    let adaptive = best_of_3(Impl::CmpAdaptive, pair, &cfg);
    // CPU accounting is best-effort (procfs); when unmeasured on either
    // side fall back to the throughput bound so the test still bites.
    if fixed.ops_per_cpu_sec > 0.0 && adaptive.ops_per_cpu_sec > 0.0 {
        assert!(
            adaptive.ops_per_cpu_sec >= fixed.ops_per_cpu_sec * 0.9,
            "adaptive idle-phase CPU efficiency regressed: {} vs {} items/CPU-s",
            adaptive.ops_per_cpu_sec,
            fixed.ops_per_cpu_sec
        );
    }
    assert!(
        adaptive.items_per_sec >= fixed.items_per_sec * 0.9,
        "adaptive bursty throughput regressed: {} vs {} items/s",
        adaptive.items_per_sec,
        fixed.items_per_sec
    );
}

/// Byte-identical default: constructing a queue without `with_adaptive`
/// leaves every published decision at its fixed-path constant, the
/// wait path on the `is_yielding` schedule, and the live `p` pinned to
/// the configured value — the "fixed-knob path remains default"
/// acceptance criterion.
#[test]
fn fixed_path_is_untouched_by_default() {
    let cfg = CmpConfig::default();
    assert!(!cfg.adaptive, "adaptive must be opt-in");
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::with_config(cfg));
    assert_eq!(q.name(), "cmp", "default queue reports the fixed name");
    let qc = q.clone();
    let consumer = std::thread::spawn(move || {
        for i in 0..3u64 {
            assert_eq!(qc.pop_blocking(), i);
        }
    });
    for i in 0..3u64 {
        std::thread::sleep(Duration::from_millis(2));
        q.push(i).unwrap();
    }
    consumer.join().unwrap();
    let snap = q.adaptive_snapshot();
    assert_eq!(
        (snap.gap_ewma_ns, snap.spin_budget),
        (0, MAX_SPIN_STEPS),
        "fixed mode never publishes gap observations"
    );
    assert_eq!(snap.live_p, q.config().bernoulli_p);
}
