//! Spec-parser contract tests for the declarative workload library
//! (DESIGN.md §14): strict unknown-key rejection with the offending
//! key named, defaulting rules, round-trip of every committed
//! `workloads/*.json`, and the zipf-exponent skew property the
//! contention knob rests on.

use std::path::Path;

use cmpq::bench::spec::{load_workload_dir, Arrival, Measure, Target, WorkloadSpec};
use cmpq::bench::workload::{PairConfig, Zipf};
use cmpq::queue::Impl;
use cmpq::util::XorShift64;

/// The committed library, relative to the crate root.
fn workload_dir() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/../workloads"))
}

#[test]
fn malformed_json_is_rejected_with_context() {
    let e = WorkloadSpec::parse("{not json").unwrap_err();
    assert!(e.contains("workload spec"), "{e}");
    let e = WorkloadSpec::parse("[1,2]").unwrap_err();
    assert!(e.contains("not an object"), "{e}");
    let e = WorkloadSpec::parse("{\"ops\":1000}").unwrap_err();
    assert!(e.contains("name"), "missing name must be called out: {e}");
}

#[test]
fn unknown_keys_are_rejected_by_name() {
    let e = WorkloadSpec::parse(r#"{"name":"t","opps":9}"#).unwrap_err();
    assert!(e.contains("\"opps\""), "top-level key must be named: {e}");
    let e =
        WorkloadSpec::parse(r#"{"name":"t","arrival":{"kind":"open","burst_sz":9}}"#).unwrap_err();
    assert!(e.contains("\"burst_sz\""), "nested key must be named: {e}");
    // Keys legal for one arrival kind are still unknown for another.
    let e =
        WorkloadSpec::parse(r#"{"name":"t","arrival":{"kind":"closed","burst":4}}"#).unwrap_err();
    assert!(e.contains("\"burst\""), "{e}");
}

#[test]
fn defaulting_rules() {
    let s = WorkloadSpec::parse(r#"{"name":"d"}"#).unwrap();
    assert_eq!(s.target, Target::Queue);
    assert_eq!(s.measure, Measure::Throughput);
    assert_eq!(
        s.impls,
        vec![Impl::Cmp, Impl::Segmented, Impl::MsHp, Impl::Mutex]
    );
    assert_eq!(
        s.pairs,
        vec![PairConfig::symmetric(1), PairConfig::symmetric(4)]
    );
    assert_eq!(s.smoke_pairs, s.pairs, "smoke_pairs defaults to pairs");
    assert_eq!(s.ops, 60_000);
    assert_eq!(s.smoke_ops, 6_000, "smoke_ops defaults to ops/10");
    assert_eq!((s.rounds, s.warmup_rounds), (3, 1));
    assert_eq!(s.batches, vec![1]);
    assert_eq!(s.arrival, Arrival::Closed);
    assert!(!s.latency, "closed loop defaults latency off");
    assert_eq!((s.keys, s.zipf_s), (0, 0.0));
    assert_eq!((s.shards, s.max_rank_error), (4, 4096));
    assert_eq!(s.sweep_max_rank_error, vec![0, 4096]);
    assert_eq!((s.clients, s.workers, s.io_threads), (8, 2, 2));
    assert_eq!((s.features, s.capacity_hint), (64, 1 << 16));
    // smoke_ops floor when ops is tiny.
    let tiny = WorkloadSpec::parse(r#"{"name":"d","ops":50}"#).unwrap();
    assert_eq!(tiny.smoke_ops, 1000);
    // Open/async arrivals flip the latency default on.
    let open = WorkloadSpec::parse(r#"{"name":"d","arrival":{"kind":"open"}}"#).unwrap();
    assert!(open.latency);
    assert_eq!(
        open.arrival,
        Arrival::Open {
            burst: 512,
            gap_ms: 2
        }
    );
}

#[test]
fn every_committed_workload_round_trips() {
    let specs = load_workload_dir(workload_dir()).expect("committed library must load");
    assert!(specs.len() >= 8, "library shrank to {}", specs.len());
    for spec in &specs {
        let back = WorkloadSpec::parse(&spec.to_json())
            .unwrap_or_else(|e| panic!("round-trip of {:?} failed: {e}", spec.name));
        assert_eq!(*spec, back, "round-trip changed {:?}", spec.name);
    }
    // The library must cover all four legacy scenarios, the sharded
    // sweep, the zipf contention knob, and both serving transports.
    let arrival_of = |name: &str| {
        specs
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("workload {name:?} missing from library"))
    };
    assert_eq!(arrival_of("closed_loop").arrival, Arrival::Closed);
    assert!(matches!(
        arrival_of("bursty").arrival,
        Arrival::Open { .. }
    ));
    assert!(matches!(arrival_of("idle").arrival, Arrival::Idle { .. }));
    assert!(matches!(
        arrival_of("async_tasks").arrival,
        Arrival::Async { .. }
    ));
    let sweep = arrival_of("rank_error_sweep");
    assert_eq!(sweep.measure, Measure::RankError);
    assert!(sweep.sweep_max_rank_error.contains(&0), "strict point");
    assert!(sweep.sweep_max_rank_error.len() >= 3, "a sweep, not modes");
    let zipf = arrival_of("zipf_contention");
    assert!(zipf.keys > 0 && zipf.zipf_s > 0.0);
    // The adaptive A/B workload: bursty↔idle alternation over the
    // fixed and adaptive CMP variants side by side (DESIGN.md §15).
    let ab = arrival_of("adaptive_burst");
    assert!(matches!(ab.arrival, Arrival::Open { .. }));
    assert!(
        ab.impls.contains(&Impl::Cmp) && ab.impls.contains(&Impl::CmpAdaptive),
        "adaptive_burst must A/B fixed vs adaptive: {:?}",
        ab.impls
    );
    assert_eq!(arrival_of("coordinator").target, Target::Coordinator);
    assert_eq!(arrival_of("tcp_ingress").target, Target::Tcp);
    // Every latency-true workload uses an honest (open-loop) arrival
    // or a request/response transport (DESIGN.md §14).
    for s in &specs {
        if s.latency && s.target == Target::Queue {
            assert!(
                s.arrival.measures_latency(),
                "{:?} reports latency from a closed loop",
                s.name
            );
        }
    }
}

#[test]
fn duplicate_names_and_empty_dirs_are_rejected() {
    let dir = std::env::temp_dir().join(format!("cmpq-wl-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let e = load_workload_dir(&dir).unwrap_err();
    assert!(e.contains("no *.json"), "{e}");
    std::fs::write(dir.join("a.json"), r#"{"name":"same"}"#).unwrap();
    std::fs::write(dir.join("b.json"), r#"{"name":"same"}"#).unwrap();
    let e = load_workload_dir(&dir).unwrap_err();
    assert!(e.contains("duplicate") && e.contains("same"), "{e}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn zipf_zero_exponent_is_uniform() {
    let n = 64;
    let z = Zipf::new(n, 0.0);
    for k in 0..n {
        let expect = (k + 1) as f64 / n as f64;
        assert!(
            (z.cdf(k) - expect).abs() < 1e-9,
            "cdf({k}) = {} != {expect}",
            z.cdf(k)
        );
    }
}

#[test]
fn higher_zipf_exponent_strictly_skews_mass_to_low_keys() {
    let n = 64;
    // P(rank ≤ k) must strictly grow with s for every prefix k < n-1:
    // more exponent, more mass on the low keys.
    let exponents = [0.0, 0.5, 1.0, 1.5, 2.0];
    for k in [0, 1, 7, 31] {
        let mut prev = -1.0;
        for &s in &exponents {
            let c = Zipf::new(n, s).cdf(k);
            assert!(
                c > prev,
                "cdf({k}) not strictly increasing in s: {c} after {prev} at s={s}"
            );
            prev = c;
        }
    }
    // Sampling sanity: at s=2 the low quarter dominates; uniform s=0
    // gives it ~a quarter.
    let draws = 20_000;
    let share = |s: f64| {
        let z = Zipf::new(n, s);
        let mut rng = XorShift64::new(7);
        let low = (0..draws).filter(|_| z.sample(&mut rng) < n / 4).count();
        low as f64 / draws as f64
    };
    let uniform = share(0.0);
    let skewed = share(2.0);
    assert!((uniform - 0.25).abs() < 0.05, "uniform low-share {uniform}");
    assert!(skewed > 0.9, "s=2 low-share only {skewed}");
}

#[test]
fn env_override_shadowing_is_applied_symmetrically() {
    // Via the testable core, not real env vars (tests run in parallel).
    let mut s =
        WorkloadSpec::parse(r#"{"name":"t","ops":60000,"smoke_ops":9000,"pairs":[8]}"#).unwrap();
    s.apply_overrides(Some("2500"), Some("1,4"));
    assert_eq!((s.ops, s.smoke_ops), (2500, 2500));
    assert_eq!(
        s.pairs,
        vec![PairConfig::symmetric(1), PairConfig::symmetric(4)]
    );
    assert_eq!(s.smoke_pairs, s.pairs);
    // Absent/garbage overrides leave the spec untouched.
    let mut s2 = WorkloadSpec::parse(r#"{"name":"t","ops":60000}"#).unwrap();
    s2.apply_overrides(None, Some(""));
    assert_eq!(s2.ops, 60_000);
    assert_eq!(
        s2.pairs,
        vec![PairConfig::symmetric(1), PairConfig::symmetric(4)]
    );
}
