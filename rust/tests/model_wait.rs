//! Model-checking the wait/claim layer (DESIGN.md §9).
//!
//! These tests run the *real* production code — `WaitStrategy`, the
//! `CmpQueue` claim/frontier core, and the `NodePool` tagged freelist —
//! under the hand-rolled schedule enumerator in `cmpq::model`. They
//! only exist under the `model-check` feature, which routes those
//! layers' atomics and mutex/condvar through the model shims; the CI
//! `model-check` job runs them with a wall-clock budget.
//!
//! Layout:
//! * exhaustive DFS passes (complete at the configured bound) over the
//!   §8 lost-wakeup race, 1P×1C in full and 2P×2C prefix-bounded;
//! * the same protocol driven through `CmpQueue::pop_blocking`;
//! * the §15 adaptive spin→park protocol with its spin budget pinned
//!   per schedule (the EWMA is sampled once per wait, so pinned
//!   budgets cover every policy the controller can emit), including a
//!   broken no-re-poll variant the checker must catch;
//! * claim-CAS vs. reclamation and freelist-ABA property scenarios;
//! * pinned adversarial schedules as named deterministic regressions;
//! * detection-power checks: deliberately broken variants (no re-poll,
//!   untagged freelist) whose bugs the checker must exhibit.
#![cfg(feature = "model-check")]

use std::collections::HashSet;
use std::sync::atomic::Ordering::SeqCst;
use std::sync::Arc;
use std::sync::Mutex as StdMutex;

use cmpq::model::{
    explore_dfs, fuzz, replay, ExploreConfig, MAtomicU64, Outcome, Scenario, ThreadBody,
};
use cmpq::queue::cmp::{CmpConfig, CmpQueue, Node, NodePool, ReclaimTrigger};
use cmpq::runtime::adaptive::MAX_SPIN_STEPS;
use cmpq::util::WaitStrategy;

/// Exhaustive prefix depth for the 2P×2C pass. Branching is ≤ 4, so
/// executions ≤ 4^depth; the 600k execution cap therefore guarantees
/// completion for any depth ≤ 9 (4^9 = 262 144). CI raises this via
/// `MODEL_DEPTH` within that bound.
fn depth_2x2() -> usize {
    std::env::var("MODEL_DEPTH")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7)
        .clamp(4, 9)
}

fn cfg_with_depth(depth: usize) -> ExploreConfig {
    ExploreConfig {
        max_depth: depth,
        max_steps: 10_000,
        max_executions: 600_000,
    }
}

// ---------------------------------------------------------------------
// The §8 eventcount race: real WaitStrategy over a model item counter.
// Thread ids: producers are 0..P, consumers are P..P+C.
// ---------------------------------------------------------------------

struct EcState {
    items: MAtomicU64,
    ws: WaitStrategy,
}

fn try_take(st: &EcState) -> bool {
    let mut cur = st.items.load(SeqCst);
    while cur > 0 {
        match st.items.compare_exchange(cur, cur - 1, SeqCst, SeqCst) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
    false
}

/// The canonical consumer protocol from DESIGN.md §8 / `park_wait`:
/// poll → register → re-poll → sleep, via the RAII registration.
fn consume_one(st: &EcState) {
    loop {
        if try_take(st) {
            return;
        }
        let registration = st.ws.registration();
        if try_take(st) {
            return; // registration drops → cancel
        }
        registration.wait();
    }
}

fn produce_one(st: &EcState) {
    st.items.fetch_add(1, SeqCst);
    st.ws.notify_if_waiting();
}

fn eventcount_scenario(producers: usize, consumers: usize, items_each: u64) -> Scenario {
    let total = producers as u64 * items_each;
    assert_eq!(total % consumers as u64, 0, "quota must divide evenly");
    let quota = total / consumers as u64;
    let st = Arc::new(EcState {
        items: MAtomicU64::new(0),
        ws: WaitStrategy::new(),
    });
    let mut threads: Vec<ThreadBody> = Vec::new();
    for _ in 0..producers {
        let st = st.clone();
        threads.push(Box::new(move || {
            for _ in 0..items_each {
                produce_one(&st);
            }
        }));
    }
    for _ in 0..consumers {
        let st = st.clone();
        threads.push(Box::new(move || {
            for _ in 0..quota {
                consume_one(&st);
            }
        }));
    }
    let st2 = st.clone();
    Scenario {
        threads,
        check: Box::new(move || {
            if st2.items.load(SeqCst) != 0 {
                return Err(format!("items left behind: {}", st2.items.load(SeqCst)));
            }
            if st2.ws.waiters() != 0 {
                return Err(format!("leaked waiters: {}", st2.ws.waiters()));
            }
            Ok(())
        }),
    }
}

/// 1 producer × 1 consumer, unbounded depth: a *complete* enumeration
/// of every SC interleaving of the 4-access race (plus its fences and
/// the sleep path). No lost wakeup (deadlock), no leaked waiter.
///
/// Head-room note: a step-faithful port of this exact scenario
/// (every atomic op, lock-acquire attempt, cv park/reacquire, and
/// RAII cancel as one scheduling point) measures **846** leaf
/// executions at ≤ 21 steps — the 600k execution cap is ~700×
/// head-room, so `complete` is a safe hard assertion.
#[test]
fn eventcount_1p1c_full_exhaustive() {
    let report = explore_dfs(|| eventcount_scenario(1, 1, 1), cfg_with_depth(100_000));
    eprintln!(
        "1P1C full: executions={} max_steps={} truncated={}",
        report.executions, report.max_steps_seen, report.depth_truncated
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
    assert!(!report.depth_truncated, "depth bound must never bind here");
    assert!(report.complete, "1P1C race must be fully enumerable");
}

/// 2 producers × 2 consumers: exhaustive over all schedule prefixes at
/// the configured bound (deterministic first-enabled completion past
/// it). This is the acceptance-criterion pass: 100% of interleavings
/// at the model's step bound, no lost wakeup, no deadlock.
#[test]
fn eventcount_2x2_exhaustive_at_bound() {
    let depth = depth_2x2();
    let report = explore_dfs(|| eventcount_scenario(2, 2, 1), cfg_with_depth(depth));
    eprintln!(
        "2P2C depth={depth}: executions={} max_steps={} truncated={}",
        report.executions, report.max_steps_seen, report.depth_truncated
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
    assert!(report.complete, "prefix space at depth {depth} must be exhausted");
}

/// Deeper 2P×2C states than the DFS bound reaches, via fixed-seed
/// random schedules. Fast (< 2 s): this is the smoke test that keeps
/// the suite usable outside the dedicated CI job.
#[test]
fn eventcount_2x2_fuzz_smoke_fixed_seed() {
    let report = fuzz(
        || eventcount_scenario(2, 2, 2),
        cfg_with_depth(0),
        0xC0FFEE,
        300,
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
}

/// Pinned adversarial interleavings of the 4-access race, replayed as
/// named deterministic regressions. Unlisted steps (and steps naming a
/// thread that is blocked/finished at that point) fall back to the
/// first enabled thread, so each run is exactly reproducible.
#[test]
fn pinned_adversarial_schedules_pass() {
    // 1P1C: producer = 0, consumer = 1.
    let pins_1p1c: [(&str, &[usize]); 3] = [
        // Producer publishes fully before the consumer looks: consumer
        // must take on the first poll, never sleeping.
        ("publish_then_poll", &[0, 0, 0, 0, 0, 0, 1, 1, 1]),
        // The classic lost-wakeup window: consumer fails its poll and
        // registers; producer publishes and reads the waiter count;
        // consumer re-polls. The re-poll (or the epoch bump) must save
        // it — this is the schedule the missing-re-poll variant dies on.
        ("publish_inside_register_window", &[1, 1, 1, 0, 0, 0, 0, 0, 0, 1]),
        // Consumer goes fully to sleep first; producer's notify path
        // must wake it (epoch bump under the lock).
        ("sleep_then_publish", &[1, 1, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0]),
    ];
    for (name, schedule) in pins_1p1c {
        let result = replay(|| eventcount_scenario(1, 1, 1), schedule, 10_000);
        assert!(
            result.outcome.is_pass(),
            "pinned schedule {name} failed: {result:?}"
        );
    }
    // 2P2C: producers = 0,1; consumers = 2,3. Both consumers park, both
    // producers publish; both must be woken and drain the queue.
    let pins_2x2: [(&str, &[usize]); 2] = [
        (
            "both_consumers_park_then_two_publishes",
            &[2, 2, 2, 2, 2, 2, 2, 3, 3, 3, 3, 3, 3, 3, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1],
        ),
        (
            "staggered_park_publish_interleave",
            &[2, 2, 2, 0, 3, 3, 3, 1, 0, 2, 1, 3, 0, 1, 2, 3],
        ),
    ];
    for (name, schedule) in pins_2x2 {
        let result = replay(|| eventcount_scenario(2, 2, 1), schedule, 10_000);
        assert!(
            result.outcome.is_pass(),
            "pinned schedule {name} failed: {result:?}"
        );
    }
}

/// Detection power: the same protocol with the register→sleep re-poll
/// removed is the textbook §8 lost wakeup, and the checker must
/// exhibit it (as a deadlock: the consumer sleeps forever while the
/// item sits in the queue). This validates that the passes above are
/// capable of failing.
#[test]
fn missing_repoll_variant_is_caught() {
    fn broken_consume_one(st: &EcState) {
        loop {
            if try_take(st) {
                return;
            }
            let registration = st.ws.registration();
            // BUG under test: no re-poll between register and sleep.
            registration.wait();
        }
    }
    let factory = || {
        let st = Arc::new(EcState {
            items: MAtomicU64::new(0),
            ws: WaitStrategy::new(),
        });
        let p = st.clone();
        let c = st.clone();
        let threads: Vec<ThreadBody> = vec![
            Box::new(move || produce_one(&p)),
            Box::new(move || broken_consume_one(&c)),
        ];
        Scenario {
            threads,
            check: Box::new(|| Ok(())),
        }
    };
    let report = explore_dfs(factory, cfg_with_depth(12));
    let cx = report
        .counterexample
        .expect("the checker must find the lost wakeup");
    assert!(
        matches!(cx.outcome, Outcome::Deadlock { .. }),
        "expected a stranded consumer, got {cx:?}"
    );
    eprintln!(
        "missing-re-poll counterexample after {} executions: schedule {:?}",
        report.executions, cx.schedule
    );
    // The counterexample schedule replays deterministically.
    let again = replay(factory, &cx.schedule, 10_000);
    assert_eq!(again.outcome, cx.outcome, "counterexample must replay");
}

// ---------------------------------------------------------------------
// The §15 adaptive wait path. `park_wait` with `config.adaptive`
// samples a spin budget once per wait and performs that many extra
// polls before the §8 register → re-poll → sleep protocol; the guard
// itself is untouched. In production the budget comes from the gap
// EWMA — but it is sampled *once*, so every concrete schedule runs
// under some pinned budget value, and enumerating pinned budgets
// covers every policy the controller can emit.
// ---------------------------------------------------------------------

/// The adaptive consumer protocol from `park_wait` (DESIGN.md §15):
/// up to `budget` spin polls (the learned phase), then the canonical
/// poll → register → re-poll → sleep. `budget = 0` is the immediate
/// park that only adaptive mode can reach; `budget = MAX_SPIN_STEPS`
/// reproduces the fixed schedule.
fn adaptive_consume_one(st: &EcState, budget: u32) {
    let mut spins = 0u32;
    loop {
        if try_take(st) {
            return;
        }
        // Spin phase: the budget never resets within one wait, exactly
        // like `backoff.step() < budget` in `park_wait`.
        if spins < budget {
            spins += 1;
            continue;
        }
        let registration = st.ws.registration();
        if try_take(st) {
            return; // registration drops → cancel
        }
        registration.wait();
    }
}

fn adaptive_scenario_1p1c(budget: u32) -> Scenario {
    let st = Arc::new(EcState {
        items: MAtomicU64::new(0),
        ws: WaitStrategy::new(),
    });
    let p = st.clone();
    let c = st.clone();
    let threads: Vec<ThreadBody> = vec![
        Box::new(move || produce_one(&p)),
        Box::new(move || adaptive_consume_one(&c, budget)),
    ];
    let st2 = st.clone();
    Scenario {
        threads,
        check: Box::new(move || {
            if st2.items.load(SeqCst) != 0 {
                return Err(format!("items left behind: {}", st2.items.load(SeqCst)));
            }
            if st2.ws.waiters() != 0 {
                return Err(format!("leaked waiters: {}", st2.ws.waiters()));
            }
            Ok(())
        }),
    }
}

/// Every pinned spin budget — from the adaptive-only immediate park
/// (0) through the fixed schedule (`MAX_SPIN_STEPS`) — fully
/// enumerated at 1P×1C: no budget value can lose the wakeup or leak a
/// waiter. Spin polls are pure re-reads, so the extra budgets grow the
/// space modestly and `complete` stays a hard assertion.
#[test]
fn adaptive_budget_pinned_exhaustive_1p1c() {
    for budget in [0, 1, 2, MAX_SPIN_STEPS] {
        let report = explore_dfs(|| adaptive_scenario_1p1c(budget), cfg_with_depth(100_000));
        eprintln!(
            "adaptive 1P1C budget={budget}: executions={} max_steps={}",
            report.executions, report.max_steps_seen
        );
        assert!(
            report.counterexample.is_none(),
            "budget {budget} counterexample: {:?}",
            report.counterexample
        );
        assert!(
            report.complete,
            "budget {budget} must be fully enumerable"
        );
    }
}

/// Heterogeneous budgets — the regime only adaptivity creates, where
/// one consumer parks immediately while its peer still spins. 2P×2C,
/// exhaustive over all schedule prefixes at the configured bound, plus
/// a fixed-seed fuzz pass beyond it.
#[test]
fn adaptive_mixed_budgets_2x2() {
    fn scenario() -> Scenario {
        let st = Arc::new(EcState {
            items: MAtomicU64::new(0),
            ws: WaitStrategy::new(),
        });
        let mut threads: Vec<ThreadBody> = Vec::new();
        for _ in 0..2 {
            let st = st.clone();
            threads.push(Box::new(move || produce_one(&st)));
        }
        for budget in [0, 2] {
            let st = st.clone();
            threads.push(Box::new(move || adaptive_consume_one(&st, budget)));
        }
        let st2 = st.clone();
        Scenario {
            threads,
            check: Box::new(move || {
                if st2.items.load(SeqCst) != 0 {
                    return Err(format!("items left behind: {}", st2.items.load(SeqCst)));
                }
                if st2.ws.waiters() != 0 {
                    return Err(format!("leaked waiters: {}", st2.ws.waiters()));
                }
                Ok(())
            }),
        }
    }
    let depth = depth_2x2();
    let report = explore_dfs(scenario, cfg_with_depth(depth));
    eprintln!(
        "adaptive 2P2C depth={depth}: executions={} truncated={}",
        report.executions, report.depth_truncated
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
    assert!(report.complete, "prefix space at depth {depth} must be exhausted");
    let fz = fuzz(scenario, cfg_with_depth(0), 0xADAF, 300);
    assert!(fz.counterexample.is_none(), "fuzz: {:?}", fz.counterexample);
}

/// Detection power for the adaptive path: spin polls are *not* a
/// substitute for the post-registration re-poll. A variant that spins
/// its whole budget but registers and sleeps without re-polling is the
/// §8 lost wakeup again, and the checker must exhibit it as a
/// stranded-consumer deadlock — proving the passes above can fail.
#[test]
fn adaptive_missing_repoll_variant_is_caught() {
    fn broken_adaptive_consume_one(st: &EcState, budget: u32) {
        let mut spins = 0u32;
        loop {
            if try_take(st) {
                return;
            }
            if spins < budget {
                spins += 1;
                continue;
            }
            let registration = st.ws.registration();
            // BUG under test: the spin phase "already polled plenty",
            // so no re-poll between register and sleep.
            registration.wait();
        }
    }
    let factory = || {
        let st = Arc::new(EcState {
            items: MAtomicU64::new(0),
            ws: WaitStrategy::new(),
        });
        let p = st.clone();
        let c = st.clone();
        let threads: Vec<ThreadBody> = vec![
            Box::new(move || produce_one(&p)),
            Box::new(move || broken_adaptive_consume_one(&c, 2)),
        ];
        Scenario {
            threads,
            check: Box::new(|| Ok(())),
        }
    };
    let report = explore_dfs(factory, cfg_with_depth(14));
    let cx = report
        .counterexample
        .expect("the checker must find the adaptive lost wakeup");
    assert!(
        matches!(cx.outcome, Outcome::Deadlock { .. }),
        "expected a stranded consumer, got {cx:?}"
    );
    let again = replay(factory, &cx.schedule, 10_000);
    assert_eq!(again.outcome, cx.outcome, "counterexample must replay");
}

// ---------------------------------------------------------------------
// The real CmpQueue under the model: parking, claim vs. reclaim.
// ---------------------------------------------------------------------

fn cmp_park_scenario() -> Scenario {
    let cfg = CmpConfig::default()
        .with_trigger(ReclaimTrigger::Manual)
        .without_magazines()
        .without_stats();
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::with_config(cfg));
    let qp = q.clone();
    let qc = q.clone();
    let threads: Vec<ThreadBody> = vec![
        Box::new(move || {
            qp.push(7).unwrap();
        }),
        Box::new(move || {
            assert_eq!(qc.pop_blocking(), 7, "FIFO single item");
        }),
    ];
    let q2 = q.clone();
    Scenario {
        threads,
        check: Box::new(move || {
            if q2.parked_consumers() != 0 {
                return Err(format!("leaked waiters: {}", q2.parked_consumers()));
            }
            if let Some(v) = q2.pop() {
                return Err(format!("item {v} left behind"));
            }
            Ok(())
        }),
    }
}

/// `push` vs. `pop_blocking` through the full queue machinery (link
/// CAS, claim CAS, cursor, frontier, eventcount park): prefix-bounded
/// exhaustive + deep fuzz, no deadlock and no lost item.
#[test]
fn cmp_queue_pop_blocking_never_strands() {
    let report = explore_dfs(cmp_park_scenario, cfg_with_depth(7));
    eprintln!(
        "cmp park DFS: executions={} max_steps={}",
        report.executions, report.max_steps_seen
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
    assert!(report.complete);
    let fz = fuzz(cmp_park_scenario, cfg_with_depth(0), 0xF00D, 300);
    assert!(fz.counterexample.is_none(), "fuzz: {:?}", fz.counterexample);
}

/// Claim CAS vs. the reclaimer with the window deliberately at its
/// minimum (`W = 1`): across all explored interleavings of two
/// consumers and a reclaimer over a preloaded queue, every item is
/// delivered exactly once or (stall-past-window semantics) dropped by
/// the reclaimer — never duplicated, never claimed out of FIFO order
/// per consumer, and never delivered from a recycled node.
fn claim_vs_reclaim_scenario() -> Scenario {
    let cfg = CmpConfig::default()
        .with_window(1)
        .with_min_batch(1)
        .with_trigger(ReclaimTrigger::Manual)
        .without_magazines();
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::with_config(cfg));
    const PRELOAD: u64 = 6;
    for i in 0..PRELOAD {
        q.push(i).unwrap(); // controller-side: not part of the schedule
    }
    let got_a = Arc::new(StdMutex::new(Vec::new()));
    let got_b = Arc::new(StdMutex::new(Vec::new()));
    let (qa, qb, qr) = (q.clone(), q.clone(), q.clone());
    let (ga, gb) = (got_a.clone(), got_b.clone());
    let threads: Vec<ThreadBody> = vec![
        Box::new(move || {
            for _ in 0..2 {
                if let Some(v) = qa.pop() {
                    ga.lock().unwrap().push(v);
                }
            }
        }),
        Box::new(move || {
            for _ in 0..2 {
                if let Some(v) = qb.pop() {
                    gb.lock().unwrap().push(v);
                }
            }
        }),
        Box::new(move || {
            qr.reclaim();
            qr.reclaim();
        }),
    ];
    Scenario {
        threads,
        check: Box::new(move || {
            let a = got_a.lock().unwrap().clone();
            let b = got_b.lock().unwrap().clone();
            for seq in [&a, &b] {
                if !seq.windows(2).all(|w| w[0] < w[1]) {
                    return Err(format!("per-consumer FIFO violated: {a:?} {b:?}"));
                }
            }
            let mut all: Vec<u64> = a.iter().chain(b.iter()).copied().collect();
            all.sort_unstable();
            let popped = all.len() as u64;
            all.dedup();
            if all.len() as u64 != popped {
                return Err(format!("duplicate delivery: {a:?} {b:?}"));
            }
            if all.iter().any(|&v| v >= PRELOAD) {
                return Err(format!("phantom value: {all:?}"));
            }
            // Remaining items drain on the controller; the reclaimer
            // accounts for any payload whose claim stalled past W.
            let mut drained = 0u64;
            while q.pop().is_some() {
                drained += 1;
            }
            let dropped = q.stats().payloads_reclaimed;
            if popped + drained + dropped != PRELOAD {
                return Err(format!(
                    "accounting broken: popped={popped} drained={drained} dropped={dropped}"
                ));
            }
            Ok(())
        }),
    }
}

#[test]
fn cmp_claim_vs_reclaim_accounting_holds() {
    let report = explore_dfs(claim_vs_reclaim_scenario, cfg_with_depth(7));
    eprintln!(
        "claim/reclaim DFS: executions={} max_steps={}",
        report.executions, report.max_steps_seen
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
    assert!(report.complete);
    let fz = fuzz(claim_vs_reclaim_scenario, cfg_with_depth(0), 0xB0B0, 400);
    assert!(fz.counterexample.is_none(), "fuzz: {:?}", fz.counterexample);
}

// ---------------------------------------------------------------------
// Freelist ABA: the real tagged pool must be clean; an untagged
// variant must be caught.
// ---------------------------------------------------------------------

/// Real `NodePool` (32-bit ABA tag beside the index): two threads
/// alloc/free over a 3-node pool while a shared ownership set asserts,
/// in-thread, that no node is ever handed to two holders at once.
fn tagged_pool_scenario() -> Scenario {
    let pool: Arc<NodePool<u64>> = Arc::new(NodePool::with_magazines(Some(3), true, 0));
    // Preload the freelist (controller side): 3 nodes through one
    // alloc/free cycle each.
    let seed: Vec<usize> = (0..3).map(|_| pool.alloc().unwrap().0 as usize).collect();
    for &p in &seed {
        // SAFETY: each pointer came from this pool's alloc above and
        // is still in its reset (FREE) state.
        unsafe { pool.free(p as *mut Node<u64>) };
    }
    let owned = Arc::new(StdMutex::new(HashSet::<usize>::new()));
    let mut threads: Vec<ThreadBody> = Vec::new();
    for _ in 0..2 {
        let pool = pool.clone();
        let owned = owned.clone();
        threads.push(Box::new(move || {
            for _ in 0..2 {
                if let Some((node, _reused)) = pool.alloc() {
                    let addr = node as usize;
                    assert!(
                        owned.lock().unwrap().insert(addr),
                        "node {addr:#x} allocated to two holders (freelist ABA)"
                    );
                    // Relinquish the claim *before* publishing the node
                    // back, so the set can never false-positive.
                    assert!(owned.lock().unwrap().remove(&addr));
                    // SAFETY: `addr` is the node this thread just
                    // allocated from this pool, untouched since.
                    unsafe { pool.free(addr as *mut Node<u64>) };
                }
            }
        }));
    }
    let pool2 = pool.clone();
    let owned2 = owned.clone();
    Scenario {
        threads,
        check: Box::new(move || {
            if !owned2.lock().unwrap().is_empty() {
                return Err("ownership set not drained".into());
            }
            if pool2.in_use() != 0 {
                return Err(format!("{} nodes leaked", pool2.in_use()));
            }
            Ok(())
        }),
    }
}

#[test]
fn pool_freelist_aba_tag_holds() {
    let report = explore_dfs(tagged_pool_scenario, cfg_with_depth(10));
    eprintln!(
        "tagged pool DFS: executions={} max_steps={}",
        report.executions, report.max_steps_seen
    );
    assert!(
        report.counterexample.is_none(),
        "counterexample: {:?}",
        report.counterexample
    );
    assert!(report.complete);
    let fz = fuzz(tagged_pool_scenario, cfg_with_depth(0), 0xABA, 400);
    assert!(fz.counterexample.is_none(), "fuzz: {:?}", fz.counterexample);
}

/// Detection power for property (c): a Treiber freelist with the tag
/// removed. The pop/push/pop interleaving re-links a stale head and
/// hands one node to two holders; the checker must exhibit it.
struct UntaggedStack {
    /// Head as index+1; 0 = empty. No generation tag — the bug.
    head: MAtomicU64,
    /// `next[i]` as index+1; 0 = none.
    next: Vec<MAtomicU64>,
}

impl UntaggedStack {
    fn new(n: usize) -> Self {
        let next = (0..n)
            .map(|i| MAtomicU64::new(if i + 1 < n { i as u64 + 2 } else { 0 }))
            .collect();
        Self {
            head: MAtomicU64::new(1),
            next,
        }
    }

    fn pop(&self) -> Option<usize> {
        let mut head = self.head.load(SeqCst);
        loop {
            if head == 0 {
                return None;
            }
            let idx = (head - 1) as usize;
            let nxt = self.next[idx].load(SeqCst);
            match self.head.compare_exchange(head, nxt, SeqCst, SeqCst) {
                Ok(_) => return Some(idx),
                Err(now) => head = now,
            }
        }
    }

    fn push(&self, idx: usize) {
        let mut head = self.head.load(SeqCst);
        loop {
            self.next[idx].store(head, SeqCst);
            match self
                .head
                .compare_exchange(head, idx as u64 + 1, SeqCst, SeqCst)
            {
                Ok(_) => return,
                Err(now) => head = now,
            }
        }
    }
}

fn untagged_stack_scenario() -> Scenario {
    fn take(stack: &UntaggedStack, owned: &StdMutex<HashSet<usize>>) -> Option<usize> {
        let idx = stack.pop()?;
        assert!(
            owned.lock().unwrap().insert(idx),
            "node {idx} popped by two holders (ABA, no tag)"
        );
        Some(idx)
    }
    let stack = Arc::new(UntaggedStack::new(3));
    let owned = Arc::new(StdMutex::new(HashSet::<usize>::new()));
    let (s1, o1) = (stack.clone(), owned.clone());
    let (s2, o2) = (stack.clone(), owned.clone());
    let threads: Vec<ThreadBody> = vec![
        // Victim: two pops; the second lands on a stale re-linked head.
        Box::new(move || {
            let _a = take(&s1, &o1);
            let _b = take(&s1, &o1);
        }),
        // Attacker: pop A, pop B, push A back — the ABA recipe.
        Box::new(move || {
            let a = take(&s2, &o2);
            let _b = take(&s2, &o2);
            if let Some(a) = a {
                assert!(o2.lock().unwrap().remove(&a));
                s2.push(a);
            }
        }),
    ];
    Scenario {
        threads,
        check: Box::new(|| Ok(())),
    }
}

#[test]
fn untagged_freelist_aba_is_caught() {
    // Fuzz finds the interleaving cheaply most of the time; the
    // depth-16 DFS (two threads → ≤ 2^16 executions) is the
    // deterministic backstop.
    let fz = fuzz(untagged_stack_scenario, cfg_with_depth(0), 0xDEAD, 6_000);
    let cx = match fz.counterexample {
        Some(cx) => {
            eprintln!("untagged ABA found by fuzz after {} executions", fz.executions);
            cx
        }
        None => {
            let report = explore_dfs(untagged_stack_scenario, cfg_with_depth(16));
            eprintln!(
                "untagged ABA DFS: executions={} complete={}",
                report.executions, report.complete
            );
            report
                .counterexample
                .expect("the checker must find the untagged-freelist ABA")
        }
    };
    assert!(
        matches!(cx.outcome, Outcome::Panicked { .. }),
        "expected the double-holder assertion, got {cx:?}"
    );
    let again = replay(untagged_stack_scenario, &cx.schedule, 10_000);
    assert_eq!(again.outcome, cx.outcome, "counterexample must replay");
}
