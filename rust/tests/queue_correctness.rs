//! Cross-implementation MPMC correctness: conservation (no loss, no
//! duplication), termination, and payload lifecycle, for every queue in
//! the registry under real thread interleavings.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use cmpq::queue::{ConcurrentQueue, Impl};

/// Run `producers`×`consumers` threads moving `per_producer` items
/// each; return everything the consumers saw.
fn run_mpmc(
    q: Arc<dyn ConcurrentQueue<u64>>,
    producers: usize,
    consumers: usize,
    per_producer: u64,
) -> Vec<u64> {
    let total = producers as u64 * per_producer;
    let done = Arc::new(AtomicBool::new(false));
    let prod: Vec<_> = (0..producers as u64)
        .map(|p| {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..per_producer {
                    q.enqueue(p * per_producer + i);
                }
            })
        })
        .collect();
    let cons: Vec<_> = (0..consumers)
        .map(|_| {
            let q = q.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match q.try_dequeue() {
                        Some(v) => got.push(v),
                        None => {
                            if done.load(Ordering::Acquire) && q.try_dequeue().is_none() {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
                got
            })
        })
        .collect();
    for h in prod {
        h.join().unwrap();
    }
    done.store(true, Ordering::Release);
    let mut all = Vec::with_capacity(total as usize);
    for h in cons {
        all.extend(h.join().unwrap());
    }
    all
}

fn check_conservation(imp: Impl, producers: usize, consumers: usize, per: u64) {
    let q: Arc<dyn ConcurrentQueue<u64>> = imp.make(1 << 15);
    let got = run_mpmc(q, producers, consumers, per);
    let total = producers as u64 * per;
    assert_eq!(got.len() as u64, total, "{}: item loss", imp.name());
    let set: HashSet<u64> = got.iter().copied().collect();
    assert_eq!(set.len() as u64, total, "{}: duplicated items", imp.name());
    for v in &set {
        assert!(*v < total, "{}: fabricated item {v}", imp.name());
    }
}

#[test]
fn conservation_2p2c_all_impls() {
    for imp in Impl::ALL {
        check_conservation(imp, 2, 2, 4_000);
    }
}

#[test]
fn conservation_4p4c_all_impls() {
    for imp in Impl::ALL {
        check_conservation(imp, 4, 4, 2_500);
    }
}

#[test]
fn conservation_asymmetric_8p2c() {
    for imp in [Impl::Cmp, Impl::MsHp, Impl::Segmented] {
        check_conservation(imp, 8, 2, 1_500);
    }
}

#[test]
fn conservation_asymmetric_2p8c() {
    for imp in [Impl::Cmp, Impl::MsEbr, Impl::Vyukov] {
        check_conservation(imp, 2, 8, 5_000);
    }
}

#[test]
fn conservation_high_contention_16p16c_cmp() {
    check_conservation(Impl::Cmp, 16, 16, 800);
}

#[test]
fn empty_dequeue_is_none_everywhere() {
    for imp in Impl::ALL {
        let q: Arc<dyn ConcurrentQueue<u64>> = imp.make(64);
        assert_eq!(q.try_dequeue(), None, "{}", imp.name());
        q.enqueue(1);
        assert_eq!(q.try_dequeue(), Some(1), "{}", imp.name());
        assert_eq!(q.try_dequeue(), None, "{}", imp.name());
    }
}

#[test]
fn payload_drop_exactly_once_under_concurrency() {
    static LIVE: AtomicUsize = AtomicUsize::new(0);
    #[derive(Debug)]
    struct Tracked;
    impl Tracked {
        fn new() -> Self {
            LIVE.fetch_add(1, Ordering::Relaxed);
            Tracked
        }
    }
    impl Drop for Tracked {
        fn drop(&mut self) {
            let prev = LIVE.fetch_sub(1, Ordering::Relaxed);
            assert!(prev > 0, "double drop detected");
        }
    }

    for imp in [Impl::Cmp, Impl::MsHp, Impl::MsEbr, Impl::Segmented] {
        LIVE.store(0, Ordering::Relaxed);
        {
            let q: Arc<dyn ConcurrentQueue<Tracked>> = imp.make(1 << 12);
            let done = Arc::new(AtomicBool::new(false));
            let prod: Vec<_> = (0..2)
                .map(|_| {
                    let q = q.clone();
                    std::thread::spawn(move || {
                        for _ in 0..2000 {
                            q.enqueue(Tracked::new());
                        }
                    })
                })
                .collect();
            let cons: Vec<_> = (0..2)
                .map(|_| {
                    let q = q.clone();
                    let done = done.clone();
                    std::thread::spawn(move || loop {
                        match q.try_dequeue() {
                            Some(t) => drop(t),
                            None => {
                                if done.load(Ordering::Acquire) && q.try_dequeue().is_none() {
                                    return;
                                }
                                std::thread::yield_now();
                            }
                        }
                    })
                })
                .collect();
            for h in prod {
                h.join().unwrap();
            }
            done.store(true, Ordering::Release);
            for h in cons {
                h.join().unwrap();
            }
            drop(q);
        }
        assert_eq!(
            LIVE.load(Ordering::Relaxed),
            0,
            "{}: leaked or double-dropped payloads",
            imp.name()
        );
    }
}

#[test]
fn large_payloads_roundtrip() {
    let q: Arc<dyn ConcurrentQueue<Vec<u8>>> = Impl::Cmp.make(0);
    for i in 0..100u8 {
        q.enqueue(vec![i; 4096]);
    }
    for i in 0..100u8 {
        let v = q.try_dequeue().unwrap();
        assert_eq!(v.len(), 4096);
        assert!(v.iter().all(|&b| b == i));
    }
}

#[test]
fn bounded_vyukov_backpressure_roundtrip() {
    let q: Arc<dyn ConcurrentQueue<u64>> = Impl::Vyukov.make(128);
    let got = run_mpmc(q, 4, 4, 2_000);
    assert_eq!(got.len(), 8_000);
}
