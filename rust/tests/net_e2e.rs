//! End-to-end tests for the TCP front end (DESIGN.md §12): loopback
//! round-trips, slow-loris read deadlines, disconnect-mid-flight
//! conservation, wire-level `Busy` under both admission layers,
//! graceful drain on shutdown, and the `/metrics` scrape contract
//! (DESIGN.md §15). Every test binds an ephemeral port, so they
//! parallelize safely.

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use cmpq::coordinator::batcher::BatchPolicy;
use cmpq::coordinator::server::{Server, ServerConfig};
use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
use cmpq::net::codec::{self, Status};
use cmpq::net::listener::NetServer;
use cmpq::net::metrics_http::{render_prometheus, MetricsServer, RenderFn};
use cmpq::net::NetConfig;

fn echo_factory() -> EngineFactory {
    Arc::new(|| {
        Ok(Box::new(EchoEngine {
            batch: 8,
            features: 2,
            outputs: 1,
            scale: 2.0,
        }) as Box<dyn InferenceEngine>)
    })
}

/// An engine that blocks every `infer` until the shared gate opens —
/// lets a test pin requests in flight deterministically.
struct GatedEngine {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl InferenceEngine for GatedEngine {
    fn batch_size(&self) -> usize {
        1
    }
    fn features_per_row(&self) -> usize {
        2
    }
    fn outputs_per_row(&self) -> usize {
        1
    }
    fn infer(&self, input: &[f32]) -> anyhow::Result<Vec<f32>> {
        let (lock, cv) = &*self.gate;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        drop(open);
        Ok(input.chunks(2).map(|c| c[0] + c[1]).collect())
    }
}

fn gated_factory(gate: Arc<(Mutex<bool>, Condvar)>) -> EngineFactory {
    Arc::new(move || {
        Ok(Box::new(GatedEngine { gate: gate.clone() }) as Box<dyn InferenceEngine>)
    })
}

fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
    *gate.0.lock().unwrap() = true;
    gate.1.notify_all();
}

fn req(id: u64, tenant: u32) -> codec::Request {
    codec::Request {
        id,
        tenant,
        features: vec![1.0, 2.0],
    }
}

fn write_req(s: &mut TcpStream, r: &codec::Request) {
    let mut wire = Vec::new();
    codec::encode_request(r, &mut wire);
    s.write_all(&wire).expect("write request");
}

fn connect(addr: std::net::SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    let timeout = Some(Duration::from_secs(10));
    s.set_read_timeout(timeout).expect("read timeout");
    s
}

/// Read one reply, panicking on EOF/error — the tests below only call
/// this where a reply is guaranteed.
fn read_reply(s: &mut TcpStream, buf: &mut Vec<u8>) -> codec::Response {
    codec::read_response_blocking(s, buf).expect("reply")
}

#[test]
fn roundtrip_across_many_connections() {
    let server = Server::start(ServerConfig::default(), echo_factory());
    let net = NetServer::start(NetConfig::default(), server).expect("bind");
    let addr = net.addr();
    let handles: Vec<_> = (0..32)
        .map(|c| {
            thread::spawn(move || {
                let mut s = connect(addr);
                let mut buf = Vec::new();
                for i in 0..8u64 {
                    write_req(&mut s, &req(i + 1, c as u32));
                    let resp = read_reply(&mut s, &mut buf);
                    assert_eq!(resp.id, i + 1);
                    assert_eq!(resp.status, Status::Ok);
                    assert_eq!(resp.output, vec![6.0], "echo: (1+2)*2");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client");
    }
    let report = net.shutdown();
    assert!(report.clean(), "clean serving ledger");
    assert_eq!(report.metrics.submitted.load(Ordering::Relaxed), 32 * 8);
    assert_eq!(report.metrics.completed.load(Ordering::Relaxed), 32 * 8);
    assert_eq!(report.net_conns_closed, 32, "every connection accounted");
}

#[test]
fn slow_client_hits_read_deadline() {
    let server = Server::start(ServerConfig::default(), echo_factory());
    let cfg = NetConfig {
        read_timeout: Duration::from_millis(200),
        ..NetConfig::default()
    };
    let net = NetServer::start(cfg, server).expect("bind");
    let mut s = connect(net.addr());
    // Half a frame: declares 16 payload bytes, delivers 2, stalls.
    s.write_all(&16u32.to_le_bytes()).unwrap();
    s.write_all(&[0u8; 2]).unwrap();
    let mut buf = Vec::new();
    let resp = read_reply(&mut s, &mut buf);
    assert_eq!(resp.status, Status::Timeout, "slow loris gets a notice");
    assert_eq!(resp.id, 0, "connection-level, not per-request");
    assert!(
        codec::read_response_blocking(&mut s, &mut buf).is_none(),
        "server drains the connection after the notice"
    );
    assert_eq!(net.metrics().read_timeouts.load(Ordering::Relaxed), 1);
    let report = net.shutdown();
    assert!(report.clean(), "nothing was ever submitted");
}

#[test]
fn disconnect_mid_flight_preserves_conservation() {
    let cfg = ServerConfig {
        // Hold the request in a partial batch long enough for the
        // client to vanish while it is in flight.
        batch_policy: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(300),
        },
        ..ServerConfig::default()
    };
    let server = Server::start(cfg, echo_factory());
    let net = NetServer::start(NetConfig::default(), server).expect("bind");
    {
        let mut s = connect(net.addr());
        write_req(&mut s, &req(1, 0));
        // Let the front end decode + submit, then drop mid-flight.
        thread::sleep(Duration::from_millis(100));
    }
    let deadline = Instant::now() + Duration::from_secs(5);
    while net.metrics().abandoned_inflight.load(Ordering::Relaxed) < 1
        && Instant::now() < deadline
    {
        thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(
        net.metrics().abandoned_inflight.load(Ordering::Relaxed),
        1,
        "the in-flight reply was abandoned at the socket"
    );
    assert_eq!(net.metrics().disconnects.load(Ordering::Relaxed), 1);
    let report = net.shutdown();
    let submitted = report.metrics.submitted.load(Ordering::Relaxed);
    let completed = report.metrics.completed.load(Ordering::Relaxed);
    assert_eq!(submitted, 1);
    assert_eq!(
        submitted, completed,
        "conservation holds without the client"
    );
}

#[test]
fn overload_returns_busy_on_the_wire() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let cfg = ServerConfig {
        max_inflight: Some(1),
        batch_policy: BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(5),
        },
        ..ServerConfig::default()
    };
    let server = Server::start(cfg, gated_factory(gate.clone()));
    let net = NetServer::start(NetConfig::default(), server).expect("bind");
    let mut s = connect(net.addr());
    // Pipeline three requests: #1 occupies the only in-flight slot
    // (the engine is gated shut); #2 and #3 are shed at admission.
    for id in 1..=3 {
        write_req(&mut s, &req(id, 0));
    }
    let mut buf = Vec::new();
    let b1 = read_reply(&mut s, &mut buf);
    let b2 = read_reply(&mut s, &mut buf);
    assert_eq!((b1.id, b1.status), (2, Status::Busy));
    assert_eq!((b2.id, b2.status), (3, Status::Busy));
    open_gate(&gate);
    let ok = read_reply(&mut s, &mut buf);
    assert_eq!((ok.id, ok.status), (1, Status::Ok));
    assert_eq!(net.metrics().busy_replies.load(Ordering::Relaxed), 2);
    drop(s);
    let report = net.shutdown();
    assert_eq!(report.metrics.shed.load(Ordering::Relaxed), 2);
    assert_eq!(report.metrics.submitted.load(Ordering::Relaxed), 1);
    assert_eq!(report.metrics.completed.load(Ordering::Relaxed), 1);
}

#[test]
fn tenant_cap_sheds_at_the_edge() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let cfg = ServerConfig {
        batch_policy: BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(5),
        },
        ..ServerConfig::default()
    };
    let server = Server::start(cfg, gated_factory(gate.clone()));
    let net_cfg = NetConfig {
        tenant_max_inflight: 1,
        ..NetConfig::default()
    };
    let net = NetServer::start(net_cfg, server).expect("bind");
    let mut s = connect(net.addr());
    // Tenant 7 pipelines two requests; its second hits the edge cap.
    // Tenant 8 is admitted regardless — per-tenant fairness.
    write_req(&mut s, &req(1, 7));
    write_req(&mut s, &req(2, 7));
    write_req(&mut s, &req(3, 8));
    let mut buf = Vec::new();
    let busy = read_reply(&mut s, &mut buf);
    assert_eq!((busy.id, busy.status), (2, Status::Busy));
    open_gate(&gate);
    let mut served: Vec<u64> = (0..2)
        .map(|_| {
            let r = read_reply(&mut s, &mut buf);
            assert_eq!(r.status, Status::Ok);
            r.id
        })
        .collect();
    served.sort_unstable();
    assert_eq!(served, vec![1, 3], "both tenants' admitted requests served");
    assert_eq!(net.metrics().tenant_busy.load(Ordering::Relaxed), 1);
    drop(s);
    let report = net.shutdown();
    assert_eq!(report.metrics.shed_tenant.load(Ordering::Relaxed), 1);
    assert_eq!(report.metrics.shed.load(Ordering::Relaxed), 1, "one ledger");
    assert_eq!(report.metrics.submitted.load(Ordering::Relaxed), 2);
    assert_eq!(report.metrics.completed.load(Ordering::Relaxed), 2);
}

#[test]
fn shutdown_drains_pending_replies_then_closes() {
    let cfg = ServerConfig {
        // A long partial-batch hold guarantees the reply is still
        // pending when shutdown begins.
        batch_policy: BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(1),
        },
        ..ServerConfig::default()
    };
    let server = Server::start(cfg, echo_factory());
    let net = NetServer::start(NetConfig::default(), server).expect("bind");
    let addr = net.addr();
    let client = thread::spawn(move || {
        let mut s = connect(addr);
        write_req(&mut s, &req(9, 0));
        let mut buf = Vec::new();
        let resp = read_reply(&mut s, &mut buf);
        assert_eq!((resp.id, resp.status), (9, Status::Ok));
        assert!(
            codec::read_response_blocking(&mut s, &mut buf).is_none(),
            "socket closes after the drain"
        );
    });
    // Request admitted and held in the batcher; now shut down.
    thread::sleep(Duration::from_millis(150));
    let report = net.shutdown();
    client.join().expect("client");
    assert!(report.net_conns_closed >= 1);
    assert!(
        report.net_drained_replies >= 1,
        "the reply flushed during drain, not before"
    );
    assert_eq!(report.metrics.submitted.load(Ordering::Relaxed), 1);
    assert_eq!(report.metrics.completed.load(Ordering::Relaxed), 1);
}

/// Parse a Prometheus text exposition, enforcing the format contract
/// the scrape test pins: every sample's family carries a `# TYPE`
/// line, no family or sample name appears twice, and every value
/// parses as a finite float. Returns `sample name → value` (this
/// exposition is label-free, so the name is the whole key).
fn parse_exposition(body: &str) -> HashMap<String, f64> {
    let mut families: HashSet<String> = HashSet::new();
    let mut samples: HashMap<String, f64> = HashMap::new();
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("TYPE line names a family").to_string();
            let kind = it.next().expect("TYPE line names a kind");
            assert!(
                matches!(kind, "counter" | "gauge"),
                "unexpected metric kind {kind:?} for {name}"
            );
            assert!(families.insert(name.clone()), "duplicate family {name}");
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or comment
        }
        let mut it = line.split_whitespace();
        let name = it.next().expect("sample line has a name");
        let value: f64 = it
            .next()
            .expect("sample line has a value")
            .parse()
            .unwrap_or_else(|e| panic!("unparseable value on {line:?}: {e}"));
        assert!(value.is_finite(), "non-finite sample {line:?}");
        assert!(it.next().is_none(), "trailing tokens on {line:?}");
        assert!(
            samples.insert(name.to_string(), value).is_none(),
            "duplicate sample {name}"
        );
    }
    for name in samples.keys() {
        assert!(families.contains(name), "{name} exported without # TYPE");
    }
    samples
}

#[test]
fn metrics_scrape_is_valid_prometheus_with_monotone_counters() {
    let server = Server::start(ServerConfig::default(), echo_factory());
    let net = NetServer::start(NetConfig::default(), server).expect("bind");
    let (srv, shared) = (net.server_handle(), net.shared_handle());
    let render: RenderFn = Arc::new(move || render_prometheus(&srv, Some(&shared)));
    let metrics = MetricsServer::start("127.0.0.1:0", render).expect("bind metrics");
    let maddr = metrics.addr();

    let scrape = move |path: &str| -> (String, String) {
        let mut c = TcpStream::connect(maddr).expect("connect scrape");
        write!(c, "GET {path} HTTP/1.0\r\nHost: t\r\n\r\n").unwrap();
        let mut out = String::new();
        c.read_to_string(&mut out).expect("scrape reply");
        let (head, body) = out.split_once("\r\n\r\n").expect("http head/body");
        (head.to_string(), body.to_string())
    };

    // Load phase one: four served requests, then a scrape.
    let mut s = connect(net.addr());
    let mut buf = Vec::new();
    for i in 1..=4u64 {
        write_req(&mut s, &req(i, 0));
        assert_eq!(read_reply(&mut s, &mut buf).status, Status::Ok);
    }
    let (head1, body1) = scrape("/metrics");
    assert!(head1.starts_with("HTTP/1.0 200 OK\r\n"), "{head1}");
    assert!(
        head1.contains("text/plain; version=0.0.4"),
        "exposition content type: {head1}"
    );
    let s1 = parse_exposition(&body1);
    // The adaptive control plane and both counter layers are exported.
    for family in [
        "cmpq_submitted_total",
        "cmpq_completed_total",
        "cmpq_spin_budget",
        "cmpq_gap_ewma_seconds",
        "cmpq_reclaim_p",
        "cmpq_batch_fill",
        "cmpq_batch_wait_seconds",
        "cmpq_net_frames_in_total",
        "cmpq_net_active_conns",
    ] {
        assert!(s1.contains_key(family), "{family} missing:\n{body1}");
    }
    assert_eq!(s1["cmpq_submitted_total"], 4.0, "serving ledger exported");

    // Load phase two: four more requests, scrape again.
    for i in 5..=8u64 {
        write_req(&mut s, &req(i, 0));
        assert_eq!(read_reply(&mut s, &mut buf).status, Status::Ok);
    }
    let (_, body2) = scrape("/metrics");
    let s2 = parse_exposition(&body2);
    for (name, v1) in &s1 {
        if !name.ends_with("_total") {
            continue; // gauges may move either way
        }
        let v2 = s2
            .get(name)
            .unwrap_or_else(|| panic!("{name} vanished between scrapes"));
        assert!(v2 >= v1, "counter {name} went backwards: {v1} -> {v2}");
    }
    assert_eq!(s2["cmpq_submitted_total"], 8.0);
    assert_eq!(s2["cmpq_net_frames_in_total"], 8.0);
    // `completed` is bumped *after* the reply is released to the slot,
    // so a scrape can trail in-flight replies by a scheduling quantum —
    // bound it instead of pinning it (monotonicity is checked above).
    assert!(
        (4.0..=8.0).contains(&s2["cmpq_completed_total"]),
        "completed ledger off: {}",
        s2["cmpq_completed_total"]
    );

    // Anything but /metrics is a 404 and never renders.
    let (head404, _) = scrape("/favicon.ico");
    assert!(head404.starts_with("HTTP/1.0 404 Not Found\r\n"), "{head404}");

    drop(s);
    // Sidecar first: shutdown joins the serving thread and releases the
    // render closure's Server handle, which `net.shutdown()` requires
    // to be unique.
    metrics.shutdown();
    let report = net.shutdown();
    assert!(report.clean(), "clean ledger after scraping under load");
}
