//! Runtime integration: load the AOT artifacts, execute through PJRT,
//! and verify numerics against the JAX-produced test vectors.
//!
//! These tests require `make artifacts`; they skip (with a loud
//! message) when the artifacts are absent so `cargo test` stays green
//! on a fresh checkout.

use std::path::PathBuf;

use cmpq::runtime::{ModelRuntime, TestVectors};

fn artifacts() -> Option<PathBuf> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature (stub runtime)");
        return None;
    }
    let dir = std::env::var_os("CMPQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    if dir.join("model.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        None
    }
}

#[test]
fn model_matches_jax_testvec() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load_from_artifacts(&dir).expect("load model");
    let tv = TestVectors::load(&dir).expect("load testvec");
    assert_eq!(rt.input_shape(), &tv.input_shape[..]);
    assert_eq!(rt.output_shape(), &tv.output_shape[..]);
    let out = rt.infer(&tv.input).expect("inference");
    tv.check(&out).expect("numerics must match JAX");
}

#[test]
fn model_rejects_wrong_input_length() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load_from_artifacts(&dir).expect("load model");
    let bad = vec![0.0f32; rt.input_len() - 1];
    assert!(rt.infer(&bad).is_err());
}

#[test]
fn model_is_deterministic_across_calls() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load_from_artifacts(&dir).expect("load model");
    let input = vec![0.25f32; rt.input_len()];
    let a = rt.infer(&input).unwrap();
    let b = rt.infer(&input).unwrap();
    assert_eq!(a, b, "same input, same executable, same output");
}

#[test]
fn model_output_depends_on_input() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load_from_artifacts(&dir).expect("load model");
    let a = rt.infer(&vec![0.1f32; rt.input_len()]).unwrap();
    let b = rt.infer(&vec![-0.4f32; rt.input_len()]).unwrap();
    assert_ne!(a, b, "model must be input-sensitive");
    assert!(a.iter().all(|x| x.is_finite()));
}

#[test]
fn synthload_artifact_executes() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir.join("synthload.hlo.txt"), vec![64, 64], vec![64, 64])
        .expect("load synthload");
    let input: Vec<f32> = (0..64 * 64).map(|i| (i as f32 * 0.001).sin() * 0.1).collect();
    let out = rt.infer(&input).expect("execute synthload");
    assert_eq!(out.len(), 64 * 64);
    assert!(out.iter().all(|x| x.is_finite()));
    assert!(out.iter().any(|&x| x != 0.0), "compute-burn must produce signal");
}

#[test]
fn multiple_runtimes_coexist() {
    // Workers each own a runtime; two instances must not interfere.
    let Some(dir) = artifacts() else { return };
    let a = ModelRuntime::load_from_artifacts(&dir).expect("runtime A");
    let b = ModelRuntime::load_from_artifacts(&dir).expect("runtime B");
    let tv = TestVectors::load(&dir).expect("testvec");
    let oa = a.infer(&tv.input).unwrap();
    let ob = b.infer(&tv.input).unwrap();
    assert_eq!(oa, ob);
}
