//! FAULT experiment as assertions (§2.3.1 / §3.6): CMP recovers from
//! crashed consumers with bounded retention; EBR's retention under a
//! pinned stall grows with churn; hazard pointers pin per-slot.

use cmpq::bench::faults::{cmp_stalled_consumer, ebr_stalled_reader, hp_stalled_reader};
use cmpq::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};

#[test]
fn cmp_bounded_retention_after_crashed_consumers() {
    let o = cmp_stalled_consumer(30_000, 8);
    assert!(
        o.bounded,
        "CMP retention {} exceeded bound {}",
        o.retained_after, o.bound
    );
}

#[test]
fn cmp_many_crashed_consumers_still_bounded() {
    let o = cmp_stalled_consumer(30_000, 64);
    assert!(o.bounded, "64 crashes: retained {}", o.retained_after);
}

#[test]
fn ebr_unbounded_retention_under_stall() {
    let o = ebr_stalled_reader(30_000);
    assert!(
        !o.bounded,
        "EBR should retain ~churn under a pinned stall, got {}",
        o.retained_after
    );
    assert!(o.retained_after as f64 >= 0.9 * 30_000.0);
}

#[test]
fn hp_pins_exactly_the_hazarded_objects() {
    let o = hp_stalled_reader(30_000);
    assert!(o.retained_after >= 1, "pinned object never freed");
    assert!(
        o.retained_after <= 65,
        "HP leak must stay per-slot bounded: {}",
        o.retained_after
    );
}

#[test]
fn cmp_crashed_producer_mid_enqueue_does_not_block_reclamation() {
    // A producer that dies *before* linking only leaks its allocated
    // node (never published). Simulate by allocating pressure, then
    // verify reclamation and operation continue.
    let q = CmpQueue::<u64>::with_config(
        CmpConfig::default()
            .with_window(128)
            .with_min_batch(1)
            .with_trigger(ReclaimTrigger::Modulo)
            .with_reclaim_period(64),
    );
    for i in 0..10_000 {
        q.push(i).unwrap();
        q.pop();
    }
    let footprint_before = q.footprint_nodes();
    for i in 0..10_000 {
        q.push(i).unwrap();
        q.pop();
    }
    assert!(
        q.footprint_nodes() <= footprint_before + 512,
        "steady state held: {} -> {}",
        footprint_before,
        q.footprint_nodes()
    );
}

#[test]
fn cmp_recovers_abandoned_payloads_within_window() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static DROPS: AtomicUsize = AtomicUsize::new(0);
    #[derive(Debug)]
    struct D;
    impl Drop for D {
        fn drop(&mut self) {
            DROPS.fetch_add(1, Ordering::Relaxed);
        }
    }
    DROPS.store(0, Ordering::Relaxed);

    let w = 128u64;
    let q = CmpQueue::<D>::with_config(
        CmpConfig::default()
            .with_window(w)
            .with_min_batch(1)
            .with_trigger(ReclaimTrigger::Manual),
    );
    // 16 consumers crash mid-dequeue.
    for _ in 0..16 {
        q.push(D).unwrap();
    }
    for _ in 0..16 {
        assert!(q.inject_stalled_claim());
    }
    assert_eq!(DROPS.load(Ordering::Relaxed), 0, "payloads stranded");
    // Slide the window past them: W+slack dequeue cycles.
    for _ in 0..(w + 64) {
        q.push(D).unwrap();
        drop(q.pop());
    }
    q.reclaim();
    let stats = q.stats();
    assert_eq!(
        stats.payloads_reclaimed, 16,
        "reclaimer must drop exactly the abandoned payloads"
    );
    assert_eq!(
        DROPS.load(Ordering::Relaxed) as u64,
        16 + w + 64,
        "crashed claims + normal pops all dropped exactly once"
    );
}
