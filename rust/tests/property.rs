//! Property-based tests (xorshift harness — proptest is not vendored,
//! DESIGN.md §3): randomized operation schedules checked against a
//! sequential `VecDeque` oracle across CMP configurations, plus
//! randomized concurrent schedules checked for conservation and
//! per-producer order.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use cmpq::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};
use cmpq::queue::{ConcurrentQueue, Impl};
use cmpq::util::XorShift64;

/// Random single-threaded schedule vs oracle: any sequential execution
/// of a linearizable FIFO queue must exactly match VecDeque.
fn check_sequential_oracle(cfg: CmpConfig, seed: u64, ops: usize) {
    let q = CmpQueue::<u64>::with_config(cfg);
    let mut oracle: VecDeque<u64> = VecDeque::new();
    let mut rng = XorShift64::new(seed);
    let mut next = 0u64;
    for step in 0..ops {
        // Mix phases: sometimes enqueue-heavy, sometimes dequeue-heavy.
        let p_enq = match (step / 500) % 3 {
            0 => 0.7,
            1 => 0.3,
            _ => 0.5,
        };
        if rng.chance(p_enq) {
            q.push(next).unwrap();
            oracle.push_back(next);
            next += 1;
        } else {
            assert_eq!(q.pop(), oracle.pop_front(), "seed={seed} step={step}");
        }
        if rng.chance(0.002) {
            q.reclaim(); // interleave explicit reclamation
        }
    }
    // Drain and compare the tail.
    loop {
        let (a, b) = (q.pop(), oracle.pop_front());
        assert_eq!(a, b, "seed={seed} drain");
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn sequential_oracle_default_config() {
    for seed in 0..8 {
        check_sequential_oracle(CmpConfig::default(), seed, 5_000);
    }
}

/// Regression (cursor stagnation): alternating push/pop keeps every
/// claim at the tail (`next == NULL`); Algorithm 3 as printed never
/// advances the cursor there, so with a tiny window the cursor node is
/// recycled and a claim on its new incarnation breaks FIFO. Our Phase 4
/// extension (advance to the claimed node) restores the §3.5 invariant.
#[test]
fn cursor_stagnation_alternating_push_pop_tiny_window() {
    let q = CmpQueue::<u64>::with_config(
        CmpConfig::default()
            .with_window(4)
            .with_min_batch(1)
            .with_reclaim_period(8),
    );
    for i in 0..50_000u64 {
        q.push(i).unwrap();
        assert_eq!(q.pop(), Some(i), "FIFO broken at {i}");
    }
    assert_eq!(q.pop(), None);
}

#[test]
fn sequential_oracle_tiny_window_aggressive_reclaim() {
    for seed in 100..106 {
        check_sequential_oracle(
            CmpConfig::default()
                .with_window(4)
                .with_min_batch(1)
                .with_reclaim_period(8),
            seed,
            5_000,
        );
    }
}

#[test]
fn sequential_oracle_no_cursor() {
    for seed in 200..204 {
        check_sequential_oracle(CmpConfig::default().without_scan_cursor(), seed, 4_000);
    }
}

#[test]
fn sequential_oracle_helping_variant() {
    for seed in 300..304 {
        check_sequential_oracle(CmpConfig::default().with_helping(), seed, 4_000);
    }
}

#[test]
fn sequential_oracle_bernoulli_trigger() {
    for seed in 400..404 {
        check_sequential_oracle(
            CmpConfig::default()
                .with_trigger(ReclaimTrigger::Bernoulli)
                .with_reclaim_period(32)
                .with_window(16)
                .with_min_batch(1),
            seed,
            4_000,
        );
    }
}

#[test]
fn sequential_oracle_bounded_pool() {
    for seed in 500..504 {
        check_sequential_oracle(
            CmpConfig::default()
                .with_max_nodes(2048)
                .with_window(64)
                .with_min_batch(1)
                .with_reclaim_period(32),
            seed,
            6_000,
        );
    }
}

/// Randomized concurrent schedule: random thread counts and op mixes;
/// assert conservation + per-producer order for strict queues.
fn check_concurrent_random(imp: Impl, seed: u64) {
    let mut rng = XorShift64::new(seed);
    let producers = 1 + rng.next_usize(4);
    let consumers = 1 + rng.next_usize(4);
    let per = 1_000 + rng.next_below(3_000);

    let q: Arc<dyn ConcurrentQueue<(u8, u64)>> = imp.make(1 << 14);
    let done = Arc::new(AtomicBool::new(false));
    let prod: Vec<_> = (0..producers as u8)
        .map(|p| {
            let q = q.clone();
            let mut prng = XorShift64::new(seed ^ (p as u64) << 32);
            std::thread::spawn(move || {
                for i in 0..per {
                    q.enqueue((p, i));
                    // Random jitter to vary interleavings.
                    if prng.chance(0.01) {
                        std::thread::yield_now();
                    }
                }
            })
        })
        .collect();
    let cons: Vec<_> = (0..consumers)
        .map(|c| {
            let q = q.clone();
            let done = done.clone();
            let mut crng = XorShift64::new(seed ^ 0xC0FFEE ^ (c as u64) << 24);
            std::thread::spawn(move || {
                let mut got: Vec<(u8, u64)> = Vec::new();
                loop {
                    match q.try_dequeue() {
                        Some(v) => got.push(v),
                        None => {
                            if done.load(Ordering::Acquire) && q.try_dequeue().is_none() {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                    if crng.chance(0.01) {
                        std::thread::yield_now();
                    }
                }
                got
            })
        })
        .collect();
    for h in prod {
        h.join().unwrap();
    }
    done.store(true, Ordering::Release);

    let mut all: Vec<(u8, u64)> = Vec::new();
    for h in cons {
        let got = h.join().unwrap();
        // Per-consumer, per-producer monotonicity (valid for ALL queue
        // types here: per-producer order is the weakest contract).
        let mut last = vec![-1i64; producers];
        for &(p, i) in &got {
            assert!(
                last[p as usize] < i as i64,
                "{} seed={seed}: consumer-local producer order violated",
                imp.name()
            );
            last[p as usize] = i as i64;
        }
        all.extend(got);
    }
    assert_eq!(all.len() as u64, producers as u64 * per, "{} seed={seed}", imp.name());
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len() as u64, producers as u64 * per, "{} seed={seed} dup", imp.name());
}

#[test]
fn concurrent_random_cmp() {
    for seed in 0..6 {
        check_concurrent_random(Impl::Cmp, seed);
    }
}

#[test]
fn concurrent_random_ms_hp() {
    for seed in 10..13 {
        check_concurrent_random(Impl::MsHp, seed);
    }
}

#[test]
fn concurrent_random_ms_ebr() {
    for seed in 20..23 {
        check_concurrent_random(Impl::MsEbr, seed);
    }
}

#[test]
fn concurrent_random_segmented() {
    for seed in 30..33 {
        check_concurrent_random(Impl::Segmented, seed);
    }
}

#[test]
fn concurrent_random_vyukov() {
    for seed in 40..43 {
        check_concurrent_random(Impl::Vyukov, seed);
    }
}

/// Zipf-skewed producers over a sharded fabric with stealing
/// consumers (DESIGN.md §13): producer activity is drawn from a
/// seeded Zipf so one producer dominates (hammering the strict head
/// shard / the relaxed round-robin unevenly) while the consumers'
/// sweep has to steal around the hot shard. Strict mode must preserve
/// each producer's subsequence at every consumer; both modes must
/// conserve. Failures print the seed — rerun with it to replay.
fn check_sharded_zipf(seed: u64, strict: bool) {
    use cmpq::bench::workload::Zipf;
    use cmpq::{ShardMode, ShardedCmp, ShardedConfig};

    let mut rng = XorShift64::new(seed);
    const PRODUCERS: usize = 4;
    const CONSUMERS: usize = 4;
    const OPS: u64 = 12_000;
    let zipf = Zipf::new(PRODUCERS, 1.2);
    let mut quota = [0u64; PRODUCERS];
    for _ in 0..OPS {
        quota[zipf.sample(&mut rng)] += 1;
    }
    let mode = if strict {
        ShardMode::Strict
    } else {
        ShardMode::Relaxed { max_rank_error: 256 }
    };
    let q: Arc<dyn ConcurrentQueue<(u8, u64)>> = Arc::new(ShardedCmp::with_config(
        ShardedConfig::default().with_shards(8).with_mode(mode),
    ));
    let done = Arc::new(AtomicBool::new(false));
    let prod: Vec<_> = (0..PRODUCERS as u8)
        .map(|p| {
            let q = q.clone();
            let n = quota[p as usize];
            let mut prng = XorShift64::new(seed ^ (p as u64) << 32);
            std::thread::spawn(move || {
                for i in 0..n {
                    q.enqueue((p, i));
                    if prng.chance(0.01) {
                        std::thread::yield_now();
                    }
                }
            })
        })
        .collect();
    let cons: Vec<_> = (0..CONSUMERS)
        .map(|_| {
            let q = q.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut got: Vec<(u8, u64)> = Vec::new();
                loop {
                    match q.try_dequeue() {
                        Some(v) => got.push(v),
                        None => {
                            if done.load(Ordering::Acquire) && q.try_dequeue().is_none() {
                                return got;
                            }
                            std::thread::yield_now();
                        }
                    }
                }
            })
        })
        .collect();
    for h in prod {
        h.join().unwrap();
    }
    done.store(true, Ordering::Release);

    let mut all: Vec<(u8, u64)> = Vec::new();
    for h in cons {
        let got = h.join().unwrap();
        if strict {
            // Strict fabric: each consumer's view of each producer is a
            // monotone subsequence, exactly as for any strict queue.
            let mut last = [-1i64; PRODUCERS];
            for &(p, i) in &got {
                assert!(
                    last[p as usize] < i as i64,
                    "sharded strict seed={seed}: producer {p} reordered"
                );
                last[p as usize] = i as i64;
            }
        }
        all.extend(got);
    }
    assert_eq!(all.len() as u64, OPS, "sharded seed={seed}: conservation");
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len() as u64, OPS, "sharded seed={seed}: duplicates");
}

#[test]
fn sharded_strict_zipf_skew_preserves_producer_order() {
    for seed in 50..53 {
        check_sharded_zipf(seed, true);
    }
}

#[test]
fn sharded_relaxed_zipf_skew_conserves() {
    for seed in 60..63 {
        check_sharded_zipf(seed, false);
    }
}

#[test]
fn concurrent_random_cmp_stress_configs() {
    // CMP with adversarial configs under concurrency.
    for (i, cfg) in [
        CmpConfig::default().with_window(8).with_min_batch(1).with_reclaim_period(4),
        CmpConfig::default().without_scan_cursor(),
        CmpConfig::default().with_helping(),
        CmpConfig::default().with_max_nodes(4096).with_window(256).with_min_batch(1),
    ]
    .into_iter()
    .enumerate()
    {
        let q: Arc<dyn ConcurrentQueue<(u8, u64)>> =
            Arc::new(CmpQueue::<(u8, u64)>::with_config(cfg));
        let done = Arc::new(AtomicBool::new(false));
        let prod: Vec<_> = (0..2u8)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for j in 0..3000 {
                        q.enqueue((p, j));
                    }
                })
            })
            .collect();
        let cons: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                let done = done.clone();
                std::thread::spawn(move || {
                    let mut n = 0u64;
                    loop {
                        match q.try_dequeue() {
                            Some(_) => n += 1,
                            None => {
                                if done.load(Ordering::Acquire) && q.try_dequeue().is_none() {
                                    return n;
                                }
                                std::thread::yield_now();
                            }
                        }
                    }
                })
            })
            .collect();
        for h in prod {
            h.join().unwrap();
        }
        done.store(true, Ordering::Release);
        let total: u64 = cons.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 6000, "config #{i}");
    }
}
