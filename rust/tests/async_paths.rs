//! Integration tests for the async bridge (DESIGN.md §10): push-side
//! waker wakeups, cancellation-on-drop, deadline futures, and the
//! executor plumbing — all through the public API.

use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::task::{Context, Poll, Wake, Waker};
use std::time::{Duration, Instant};

use cmpq::queue::Impl;
use cmpq::util::executor::{block_on, Executor};
use cmpq::{CmpQueue, ConcurrentQueue};

/// Counting test waker (manual poll harness).
struct CountWake(AtomicUsize);

impl Wake for CountWake {
    fn wake(self: Arc<Self>) {
        self.0.fetch_add(1, Ordering::SeqCst);
    }
}

fn test_waker() -> (Arc<CountWake>, Waker) {
    let cw = Arc::new(CountWake(AtomicUsize::new(0)));
    let waker = Waker::from(cw.clone());
    (cw, waker)
}

fn poll_once<F: Future + Unpin>(fut: &mut F, waker: &Waker) -> Poll<F::Output> {
    let mut cx = Context::from_waker(waker);
    Pin::new(fut).poll(&mut cx)
}

#[test]
fn wake_on_push_resolves_pending_future() {
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
    assert_eq!(q.parked_consumers(), 0, "fast path: nobody registered");
    let q2 = q.clone();
    let consumer = std::thread::spawn(move || block_on(q2.pop_async()));
    // Wait until the future's waker slot is registered (the same
    // counter that gates the producer's notify slow path).
    let until = Instant::now() + Duration::from_secs(10);
    while q.parked_consumers() == 0 && Instant::now() < until {
        std::thread::yield_now();
    }
    assert_eq!(q.parked_consumers(), 1, "future registered one slot");
    q.push(42).unwrap();
    assert_eq!(consumer.join().unwrap(), 42);
    assert_eq!(q.parked_consumers(), 0, "resolution freed the slot");
}

#[test]
fn drop_before_wake_leaks_no_waker_slot() {
    // Regression shape: a future polled to Pending and then cancelled
    // must deregister its slot — a leak here would permanently force
    // every push onto the notify lock path (and `parked_consumers`
    // would never return to 0).
    let q: CmpQueue<u64> = CmpQueue::new();
    let (_cw, waker) = test_waker();
    for round in 0..100 {
        let mut fut = q.pop_async();
        assert!(poll_once(&mut fut, &waker).is_pending());
        assert_eq!(q.parked_consumers(), 1, "round {round}");
        drop(fut);
        assert_eq!(q.parked_consumers(), 0, "round {round}: slot leaked");
    }
    // The push fast path is intact after all that churn.
    q.push(7).unwrap();
    assert_eq!(q.pop(), Some(7));
}

#[test]
fn dropped_future_never_strands_an_element() {
    // Push lands after registration (the future is woken), then the
    // future is dropped without being re-polled: the element must stay
    // claimable by anyone else.
    let q: CmpQueue<u64> = CmpQueue::new();
    let (cw, waker) = test_waker();
    let mut fut = q.pop_async();
    assert!(poll_once(&mut fut, &waker).is_pending());
    q.push(9).unwrap();
    assert_eq!(cw.0.load(Ordering::SeqCst), 1, "push woke the task");
    drop(fut);
    assert_eq!(q.parked_consumers(), 0);
    assert_eq!(q.pop(), Some(9), "woken-then-cancelled strands nothing");
}

#[test]
fn deadline_future_times_out_empty() {
    // CMP (timer-driven expiry) and a baseline (polling default) agree
    // on the timeout contract.
    for i in [Impl::Cmp, Impl::Mutex] {
        let q: Arc<dyn ConcurrentQueue<u64>> = i.make(64);
        let t0 = Instant::now();
        let out = block_on(q.pop_deadline_async(t0 + Duration::from_millis(40)));
        assert_eq!(out, None, "{}", i.name());
        assert!(
            t0.elapsed() >= Duration::from_millis(40),
            "{} returned early",
            i.name()
        );
    }
}

#[test]
fn deadline_future_resolves_on_late_push() {
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
    let q2 = q.clone();
    let consumer = std::thread::spawn(move || {
        block_on(q2.pop_deadline_async(Instant::now() + Duration::from_secs(30)))
    });
    let until = Instant::now() + Duration::from_secs(10);
    while q.parked_consumers() == 0 && Instant::now() < until {
        std::thread::yield_now();
    }
    q.push(5).unwrap();
    assert_eq!(consumer.join().unwrap(), Some(5), "woken before expiry");
    assert_eq!(q.parked_consumers(), 0);
}

#[test]
fn many_futures_one_push_wakes_exactly_one_into_the_item() {
    // Four tasks pend on one queue; one push arrives. The notification
    // wakes every registered waker (like notify_all), but exactly one
    // future can claim the item and resolve `Some` — the rest
    // re-register and time out.
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
    let consumers: Vec<_> = (0..4)
        .map(|_| {
            let q = q.clone();
            std::thread::spawn(move || {
                // Per-thread window: starts at registration time, so a
                // slow spawn cannot eat into it.
                let deadline = Instant::now() + Duration::from_secs(2);
                block_on(q.pop_deadline_async(deadline))
            })
        })
        .collect();
    let until = Instant::now() + Duration::from_secs(10);
    while q.parked_consumers() < 4 && Instant::now() < until {
        std::thread::yield_now();
    }
    assert_eq!(q.parked_consumers(), 4);
    q.push(77).unwrap();
    let results: Vec<_> = consumers.into_iter().map(|h| h.join().unwrap()).collect();
    let hits: Vec<_> = results.iter().filter_map(|r| *r).collect();
    assert_eq!(hits, vec![77], "exactly one future resolved the item");
    assert_eq!(q.parked_consumers(), 0, "losers deregistered at expiry");
    assert_eq!(q.pop(), None, "no duplicate claim");
}

#[test]
fn pop_async_batch_claims_runs_in_order() {
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
    let q2 = q.clone();
    let consumer = std::thread::spawn(move || block_on(q2.pop_async_batch(8)));
    let until = Instant::now() + Duration::from_secs(10);
    while q.parked_consumers() == 0 && Instant::now() < until {
        std::thread::yield_now();
    }
    q.push_batch(vec![1, 2, 3]).unwrap();
    let run = consumer.join().unwrap();
    assert!(!run.is_empty() && run[0] == 1, "FIFO claim: {run:?}");
}

#[test]
fn executor_fleet_drains_queue_without_loss() {
    // 8 async consumer tasks on one executor thread vs 2 producer
    // threads: every item is consumed exactly once, with no dedicated
    // thread per consumer.
    const TOTAL: u64 = 4_000;
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
    let consumed = Arc::new(std::sync::Mutex::new(Vec::new()));
    let producers_done = Arc::new(AtomicUsize::new(0));
    let producers: Vec<_> = (0..2u64)
        .map(|p| {
            let q = q.clone();
            let producers_done = producers_done.clone();
            std::thread::spawn(move || {
                for i in 0..TOTAL / 2 {
                    q.push(p * (TOTAL / 2) + i).unwrap();
                }
                producers_done.fetch_add(1, Ordering::SeqCst);
            })
        })
        .collect();
    let mut ex = Executor::new();
    let done = Arc::new(AtomicUsize::new(0));
    for _ in 0..8 {
        let q = q.clone();
        let consumed = consumed.clone();
        let done = done.clone();
        let producers_done = producers_done.clone();
        ex.spawn(async move {
            let mut empty_slices = 0u32;
            loop {
                let slice = Instant::now() + Duration::from_millis(50);
                match q.pop_deadline_async(slice).await {
                    Some(v) => {
                        consumed.lock().unwrap().push(v);
                        empty_slices = 0;
                    }
                    None => {
                        // Drained only once the producers finished and
                        // two consecutive full slices stayed empty.
                        if producers_done.load(Ordering::SeqCst) == 2 {
                            empty_slices += 1;
                            if empty_slices >= 2 {
                                break;
                            }
                        }
                    }
                }
            }
            done.fetch_add(1, Ordering::SeqCst);
        });
    }
    ex.run();
    for p in producers {
        p.join().unwrap();
    }
    assert_eq!(done.load(Ordering::SeqCst), 8, "all tasks exited");
    let mut all = consumed.lock().unwrap().clone();
    assert_eq!(all.len() as u64, TOTAL, "no loss");
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len() as u64, TOTAL, "no duplicates");
    assert_eq!(q.parked_consumers(), 0);
}

#[test]
fn async_defaults_work_through_trait_objects() {
    for i in Impl::ALL {
        let q: Arc<dyn ConcurrentQueue<u64>> = i.make(1024);
        q.enqueue(1);
        assert_eq!(block_on(q.pop_async()), 1, "{}", i.name());
        q.try_enqueue_batch(vec![2, 3]).unwrap();
        let run = block_on(q.pop_async_batch(4));
        assert_eq!(run.len(), 2, "{}", i.name());
    }
}
