//! Batch-operation and per-thread-magazine coverage (DESIGN.md §7):
//!
//! * FIFO-order property tests interleaving `push_batch` / `pop_batch`
//!   with single ops, sequentially (vs a `VecDeque` oracle) and across
//!   threads (conservation + per-producer order).
//! * Magazine lifecycle: flush-on-thread-exit leaves no nodes stranded
//!   in dead threads' caches (`nodes_in_use` is fully accounted by the
//!   linked list after drain + join + flush).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use cmpq::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};
use cmpq::queue::ConcurrentQueue;
use cmpq::util::XorShift64;

/// Random schedule of single and batch ops vs a sequential oracle.
fn check_batch_oracle(cfg: CmpConfig, seed: u64, steps: usize) {
    let q = CmpQueue::<u64>::with_config(cfg);
    let mut oracle: VecDeque<u64> = VecDeque::new();
    let mut rng = XorShift64::new(seed);
    let mut next = 0u64;
    for step in 0..steps {
        match rng.next_below(4) {
            0 => {
                q.push(next).unwrap();
                oracle.push_back(next);
                next += 1;
            }
            1 => {
                let k = 1 + rng.next_below(16);
                q.push_batch((next..next + k).collect()).unwrap();
                oracle.extend(next..next + k);
                next += k;
            }
            2 => {
                assert_eq!(q.pop(), oracle.pop_front(), "seed={seed} step={step}");
            }
            _ => {
                let k = 1 + rng.next_usize(16);
                let got = q.pop_batch(k);
                let want: Vec<u64> =
                    (0..k).filter_map(|_| oracle.pop_front()).collect();
                assert_eq!(got, want, "seed={seed} step={step}");
            }
        }
        if rng.chance(0.002) {
            q.reclaim();
        }
    }
    // Drain both and compare the tails.
    loop {
        let (a, b) = (q.pop(), oracle.pop_front());
        assert_eq!(a, b, "seed={seed} drain");
        if a.is_none() {
            break;
        }
    }
}

#[test]
fn batch_oracle_default_config() {
    for seed in 0..6 {
        check_batch_oracle(CmpConfig::default(), seed, 3_000);
    }
}

#[test]
fn batch_oracle_tiny_window_aggressive_reclaim() {
    for seed in 100..104 {
        check_batch_oracle(
            CmpConfig::default()
                .with_window(4)
                .with_min_batch(1)
                .with_reclaim_period(8),
            seed,
            3_000,
        );
    }
}

#[test]
fn batch_oracle_without_magazines() {
    for seed in 200..203 {
        check_batch_oracle(CmpConfig::default().without_magazines(), seed, 3_000);
    }
}

#[test]
fn batch_oracle_without_cursor_bernoulli_trigger() {
    for seed in 300..303 {
        check_batch_oracle(
            CmpConfig::default()
                .without_scan_cursor()
                .with_trigger(ReclaimTrigger::Bernoulli)
                .with_reclaim_period(32)
                .with_window(64)
                .with_min_batch(1),
            seed,
            3_000,
        );
    }
}

/// Concurrent FIFO property: producers mix `push` and `push_batch`,
/// consumers mix `pop` and `pop_batch`. Checks conservation (no loss,
/// no duplication) and per-producer monotonic order — the observable
/// strict-FIFO contract under MPMC.
fn check_concurrent_batch_fifo(cfg: CmpConfig, seed: u64) {
    let producers = 3usize;
    let consumers = 3usize;
    let per = 6_000u64;
    let q: Arc<CmpQueue<(u8, u64)>> = Arc::new(CmpQueue::with_config(cfg));
    let done = Arc::new(AtomicBool::new(false));

    let prod: Vec<_> = (0..producers as u8)
        .map(|p| {
            let q = q.clone();
            let mut rng = XorShift64::new(seed ^ ((p as u64) << 32));
            std::thread::spawn(move || {
                let mut i = 0u64;
                while i < per {
                    if rng.chance(0.5) {
                        let k = (1 + rng.next_below(12)).min(per - i);
                        q.push_batch((i..i + k).map(|j| (p, j)).collect())
                            .unwrap();
                        i += k;
                    } else {
                        q.push((p, i)).unwrap();
                        i += 1;
                    }
                }
            })
        })
        .collect();
    let cons: Vec<_> = (0..consumers)
        .map(|c| {
            let q = q.clone();
            let done = done.clone();
            let mut rng = XorShift64::new(seed ^ 0xBA7C4 ^ ((c as u64) << 24));
            std::thread::spawn(move || {
                let mut got: Vec<(u8, u64)> = Vec::new();
                let mut buf: Vec<(u8, u64)> = Vec::new();
                loop {
                    let n = if rng.chance(0.5) {
                        q.pop_batch_into(1 + rng.next_usize(12), &mut buf)
                    } else {
                        match q.pop() {
                            Some(v) => {
                                buf.push(v);
                                1
                            }
                            None => 0,
                        }
                    };
                    if n > 0 {
                        got.append(&mut buf);
                    } else if done.load(Ordering::Acquire) {
                        // Exit probe must not drop a claimed item.
                        match q.pop() {
                            Some(v) => got.push(v),
                            None => break,
                        }
                    } else {
                        std::thread::yield_now();
                    }
                }
                got
            })
        })
        .collect();

    for h in prod {
        h.join().unwrap();
    }
    done.store(true, Ordering::Release);
    let mut all: Vec<(u8, u64)> = Vec::new();
    for h in cons {
        let got = h.join().unwrap();
        // Per-consumer, per-producer monotonicity: a strict-FIFO queue
        // can never show one consumer producer-p items out of order,
        // whether they were claimed singly or in runs.
        let mut last = vec![-1i64; producers];
        for &(p, i) in &got {
            assert!(
                last[p as usize] < i as i64,
                "seed={seed}: consumer-local producer order violated"
            );
            last[p as usize] = i as i64;
        }
        all.extend(got);
    }
    let total = producers as u64 * per;
    assert_eq!(all.len() as u64, total, "seed={seed}: no loss");
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len() as u64, total, "seed={seed}: no duplicates");
}

#[test]
fn concurrent_batch_fifo_default() {
    for seed in 0..3 {
        check_concurrent_batch_fifo(CmpConfig::default(), seed);
    }
}

#[test]
fn concurrent_batch_fifo_small_window() {
    for seed in 10..12 {
        check_concurrent_batch_fifo(
            CmpConfig::default()
                .with_window(256)
                .with_min_batch(1)
                .with_reclaim_period(64),
            seed,
        );
    }
}

#[test]
fn concurrent_batch_fifo_without_magazines() {
    for seed in 20..22 {
        check_concurrent_batch_fifo(CmpConfig::default().without_magazines(), seed);
    }
}

/// SPSC with batches: the one setting where *global* FIFO order is
/// directly observable end to end.
#[test]
fn spsc_batch_global_order() {
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
    let total = 50_000u64;
    let producer = {
        let q = q.clone();
        std::thread::spawn(move || {
            let mut i = 0u64;
            let mut rng = XorShift64::new(7);
            while i < total {
                let k = (1 + rng.next_below(32)).min(total - i);
                q.push_batch((i..i + k).collect()).unwrap();
                i += k;
            }
        })
    };
    let mut expect = 0u64;
    let mut buf = Vec::new();
    let mut rng = XorShift64::new(11);
    while expect < total {
        let n = q.pop_batch_into(1 + rng.next_usize(32), &mut buf);
        for v in buf.drain(..) {
            assert_eq!(v, expect, "global FIFO order");
            expect += 1;
        }
        if n == 0 {
            std::thread::yield_now();
        }
    }
    producer.join().unwrap();
    assert_eq!(q.pop(), None);
}

/// Magazine-flush-on-thread-exit leak test (ISSUE acceptance): after
/// worker threads churn the queue and exit, every pool node must be
/// accounted for by the linked list + global freelist — nothing
/// stranded in dead threads' magazines.
#[test]
fn magazine_flush_on_thread_exit_leaves_no_stranded_nodes() {
    let window = 64u64;
    let cfg = CmpConfig::default()
        .with_window(window)
        .with_min_batch(1)
        .with_reclaim_period(32);
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::with_config(cfg));

    let handles: Vec<_> = (0..4)
        .map(|t| {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut rng = XorShift64::new(0x5EED ^ t as u64);
                let mut i = 0u64;
                while i < 20_000 {
                    if rng.chance(0.4) {
                        let k = 1 + rng.next_below(8);
                        q.push_batch((i..i + k).collect()).unwrap();
                        i += k;
                    } else {
                        q.push(i).unwrap();
                        i += 1;
                    }
                    q.pop_batch(4);
                }
                // Exit with whatever the magazine holds: the TLS
                // destructor must hand it back.
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // Drain leftovers and settle reclamation from the main thread.
    while q.pop().is_some() {}
    loop {
        if q.reclaim() == 0 {
            break;
        }
    }
    q.flush_thread_cache();
    assert_eq!(q.thread_cached_nodes(), 0, "main-thread magazine flushed");

    // Exact accounting: every node outside the global freelist is
    // reachable from head. If a dead thread's magazine had leaked,
    // in_use would exceed the linked count permanently.
    assert_eq!(
        q.nodes_in_use(),
        q.debug_linked_nodes(),
        "nodes stranded outside the list (magazine leak)"
    );
    // And the linked remainder is bounded by the protection window plus
    // the unreclaimable boundary nodes (tail + dummy) plus a small
    // slack for cycle disorder left by concurrent batch links (the
    // reclaimer stops at the first in-window cycle it sees) — dummy +
    // window, not a growing leak.
    assert!(
        q.debug_linked_nodes() <= window + 40,
        "linked remainder {} exceeds window bound",
        q.debug_linked_nodes()
    );
}

/// Magazine caching is observable (nodes cached locally) and bounded by
/// the configured capacity.
#[test]
fn magazine_cache_is_bounded_by_capacity() {
    let cap = 16usize;
    let cfg = CmpConfig::default()
        .with_magazine_capacity(cap)
        .with_min_batch(1)
        .with_window(1)
        .with_trigger(ReclaimTrigger::Manual);
    let q: CmpQueue<u64> = CmpQueue::with_config(cfg);
    // Build up a recycled population, then churn so allocs refill from
    // the freelist through the magazine.
    for i in 0..1_000u64 {
        q.push(i).unwrap();
        q.pop();
        if i % 64 == 0 {
            q.reclaim();
        }
    }
    q.reclaim();
    for i in 0..64u64 {
        q.push(i).unwrap();
        q.pop();
    }
    assert!(
        q.thread_cached_nodes() <= cap,
        "magazine {} exceeds capacity {cap}",
        q.thread_cached_nodes()
    );
    q.flush_thread_cache();
    assert_eq!(q.thread_cached_nodes(), 0);
}

/// The batch API surfaces through the `ConcurrentQueue` trait object.
#[test]
fn cmp_batch_api_via_trait_object() {
    let q: Arc<dyn ConcurrentQueue<u64>> = Arc::new(CmpQueue::<u64>::new());
    q.try_enqueue_batch((0..100).collect()).unwrap();
    let mut out = Vec::new();
    assert_eq!(q.try_dequeue_batch(100, &mut out), 100);
    assert_eq!(out, (0..100).collect::<Vec<_>>());
}
