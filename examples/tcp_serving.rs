//! Serving over TCP: the inference pipeline behind the crate's own
//! dependency-free network front end.
//!
//! ```sh
//! cargo run --release --example tcp_serving
//! ```
//!
//! Demonstrates the reactor-driven TCP ingress (DESIGN.md §12): a
//! couple of I/O threads multiplex every connection through
//! nonblocking sockets and the crate's executor, decode the
//! length-prefixed wire format, admit per tenant, and feed
//! `submit_async_for_tenant`. Clients here are plain blocking
//! `std::net::TcpStream`s — the wire format is the only contract.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use cmpq::coordinator::server::{Server, ServerConfig};
use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
use cmpq::net::codec::{self, Request, Status};
use cmpq::net::listener::NetServer;
use cmpq::net::NetConfig;

fn main() {
    const CLIENTS: u32 = 8;
    const PER_CLIENT: u64 = 200;
    const FEATURES: usize = 16;

    // 1. The serving pipeline: router → batcher → echo workers.
    let factory: EngineFactory = Arc::new(|| {
        Ok(Box::new(EchoEngine {
            batch: 8,
            features: FEATURES,
            outputs: 1,
            scale: 2.0,
        }) as Box<dyn InferenceEngine>)
    });
    let server = Server::start(ServerConfig::default(), factory);

    // 2. The TCP front end: ephemeral port, two I/O threads, a light
    //    per-tenant in-flight cap.
    let net = NetServer::start(
        NetConfig {
            io_threads: 2,
            tenant_max_inflight: 64,
            ..NetConfig::default()
        },
        server,
    )
    .expect("bind TCP front end");
    let addr = net.addr();
    println!("listening on {addr} — {CLIENTS} clients × {PER_CLIENT} requests");

    // 3. Blocking clients: one connection each, one request in flight
    //    at a time, each client its own tenant id.
    let t0 = Instant::now();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|tenant| {
            thread::spawn(move || {
                let mut s = TcpStream::connect(addr).expect("connect");
                let mut buf = Vec::new();
                let mut ok = 0u64;
                for id in 1..=PER_CLIENT {
                    let req = Request {
                        id,
                        tenant,
                        features: vec![tenant as f32; FEATURES],
                    };
                    let mut wire = Vec::new();
                    codec::encode_request(&req, &mut wire);
                    s.write_all(&wire).expect("send");
                    let Some(resp) = codec::read_response_blocking(&mut s, &mut buf) else {
                        panic!("server closed before replying");
                    };
                    assert_eq!(resp.id, id, "replies correlate by id");
                    if resp.status == Status::Ok {
                        ok += 1;
                    }
                }
                ok
            })
        })
        .collect();
    let served: u64 = handles.into_iter().map(|h| h.join().expect("client")).sum();
    let dt = t0.elapsed();
    println!(
        "served {served} requests over TCP in {dt:.2?} ({:.0} req/s)",
        served as f64 / dt.as_secs_f64()
    );

    // 4. Graceful shutdown: connections drain, then the server stops;
    //    the report folds both ledgers together.
    println!("{}", net.metrics().report());
    let report = net.shutdown();
    println!("{}", report.metrics.report());
    println!(
        "net: conns_closed={} drained_replies={} clean={}",
        report.net_conns_closed,
        report.net_drained_replies,
        report.clean()
    );
}
