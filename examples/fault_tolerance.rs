//! Fault tolerance (§2.3.1, §3.6): what a stalled or crashed
//! participant does to each reclamation scheme.
//!
//! * CMP — consumers crash right after their claim CAS: reclamation
//!   recovers the abandoned nodes after W cycles; memory stays bounded.
//! * EBR — a thread stalls while pinned: retention grows with churn.
//! * Hazard pointers — a never-cleared hazard pins its node forever.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! ```

use cmpq::bench::faults::{
    cmp_stalled_consumer, ebr_stalled_reader, fault_table, hp_stalled_reader,
};
use cmpq::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};

fn main() {
    let churn = 50_000;

    println!("Injecting faults and churning {churn} ops through each scheme...\n");
    let outcomes = vec![
        cmp_stalled_consumer(churn, 8),
        hp_stalled_reader(churn),
        ebr_stalled_reader(churn),
    ];
    println!("{}", fault_table(&outcomes));

    println!("Interpretation:");
    println!("  cmp    — 8 consumers crashed mid-dequeue; retention stays ≈ W.");
    println!("  ms-hp  — the pinned node leaks until the thread recovers (leak ∝ pinned slots).");
    println!("  ms-ebr — a single pinned stall blocks ALL reclamation: retention ≈ churn.\n");

    // Bounded-recovery detail for CMP: watch the abandoned payloads get
    // dropped by the reclaimer as the window slides past them.
    let cfg = CmpConfig::default()
        .with_window(256)
        .with_min_batch(1)
        .with_trigger(ReclaimTrigger::Manual);
    let q: CmpQueue<Vec<u8>> = CmpQueue::with_config(cfg);
    for i in 0..64u8 {
        q.push(vec![i; 16]).unwrap();
    }
    for _ in 0..8 {
        assert!(q.inject_stalled_claim(), "claim injected");
    }
    // Drain the rest normally, then slide the window far past the
    // abandoned claims.
    while q.pop().is_some() {}
    for i in 0..1024u64 {
        q.push(vec![i as u8; 4]).unwrap();
        q.pop();
    }
    let freed = q.reclaim();
    let stats = q.stats();
    println!("CMP recovery detail:");
    println!("  nodes recycled this pass: {freed}");
    println!(
        "  payloads recovered from crashed claimers: {}",
        stats.payloads_reclaimed
    );
    println!("  pool footprint: {} nodes", q.footprint_nodes());
    assert!(stats.payloads_reclaimed >= 8, "all abandoned payloads dropped");
    println!("\nCMP recovered every abandoned node without any coordination. ✓");
}
