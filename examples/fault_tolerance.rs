//! Fault tolerance (§2.3.1, §3.6): what a stalled or crashed
//! participant does to each reclamation scheme.
//!
//! * CMP — consumers crash right after their claim CAS: reclamation
//!   recovers the abandoned nodes after W cycles; memory stays bounded.
//! * EBR — a thread stalls while pinned: retention grows with churn.
//! * Hazard pointers — a never-cleared hazard pins its node forever.
//!
//! Plus the coordinator layer (DESIGN.md §11): a worker that panics
//! mid-batch NACKs every claimed request and is respawned by its
//! supervisor — requests resolve with an explicit error, never strand.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use cmpq::bench::faults::{
    cmp_stalled_consumer, ebr_stalled_reader, fault_table, hp_stalled_reader,
};
use cmpq::coordinator::batcher::BatchPolicy;
use cmpq::coordinator::request::InferError;
use cmpq::coordinator::server::{Server, ServerConfig};
use cmpq::coordinator::worker::{EngineFactory, InferenceEngine};
use cmpq::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};

fn main() {
    let churn = 50_000;

    println!("Injecting faults and churning {churn} ops through each scheme...\n");
    let outcomes = vec![
        cmp_stalled_consumer(churn, 8),
        hp_stalled_reader(churn),
        ebr_stalled_reader(churn),
    ];
    println!("{}", fault_table(&outcomes));

    println!("Interpretation:");
    println!("  cmp    — 8 consumers crashed mid-dequeue; retention stays ≈ W.");
    println!("  ms-hp  — the pinned node leaks until the thread recovers (leak ∝ pinned slots).");
    println!("  ms-ebr — a single pinned stall blocks ALL reclamation: retention ≈ churn.\n");

    // Bounded-recovery detail for CMP: watch the abandoned payloads get
    // dropped by the reclaimer as the window slides past them.
    let cfg = CmpConfig::default()
        .with_window(256)
        .with_min_batch(1)
        .with_trigger(ReclaimTrigger::Manual);
    let q: CmpQueue<Vec<u8>> = CmpQueue::with_config(cfg);
    for i in 0..64u8 {
        q.push(vec![i; 16]).unwrap();
    }
    for _ in 0..8 {
        assert!(q.inject_stalled_claim(), "claim injected");
    }
    // Drain the rest normally, then slide the window far past the
    // abandoned claims.
    while q.pop().is_some() {}
    for i in 0..1024u64 {
        q.push(vec![i as u8; 4]).unwrap();
        q.pop();
    }
    let freed = q.reclaim();
    let stats = q.stats();
    println!("CMP recovery detail:");
    println!("  nodes recycled this pass: {freed}");
    println!(
        "  payloads recovered from crashed claimers: {}",
        stats.payloads_reclaimed
    );
    println!("  pool footprint: {} nodes", q.footprint_nodes());
    assert!(stats.payloads_reclaimed >= 8, "all abandoned payloads dropped");
    println!("\nCMP recovered every abandoned node without any coordination. ✓");

    coordinator_panic_demo();
}

/// Echo engine whose FIRST inference panics. The trip flag lives
/// outside the engine, so the respawned worker's fresh instance serves
/// normally — a crash-once model bug, not a permanently broken one.
struct FlakyEcho {
    tripped: Arc<AtomicBool>,
}

impl InferenceEngine for FlakyEcho {
    fn batch_size(&self) -> usize {
        4
    }
    fn features_per_row(&self) -> usize {
        2
    }
    fn outputs_per_row(&self) -> usize {
        1
    }
    fn infer(&self, input: &[f32]) -> anyhow::Result<Vec<f32>> {
        if !self.tripped.swap(true, Ordering::SeqCst) {
            panic!("model bug: first inference dies");
        }
        Ok(input.chunks(2).map(|row| row[0] + row[1]).collect())
    }
}

/// Worker supervision end to end: panic mid-batch → NACK (an explicit
/// `WorkerPanicked` error, not a hung client) → supervisor respawn →
/// the next request is served — and the shutdown report says exactly
/// what happened.
fn coordinator_panic_demo() {
    println!("\nCoordinator-layer fault tolerance (worker panic mid-batch):");
    let tripped = Arc::new(AtomicBool::new(false));
    let factory: EngineFactory = {
        let tripped = tripped.clone();
        Arc::new(move || {
            Ok(Box::new(FlakyEcho {
                tripped: tripped.clone(),
            }) as Box<dyn InferenceEngine>)
        })
    };
    let server = Server::start(
        ServerConfig {
            shards: 1,
            workers: 1,
            batch_policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            ..ServerConfig::default()
        },
        factory,
    );

    // Request 1 rides the batch that panics: it resolves with a NACK.
    let slot = server.submit(vec![1.0, 2.0]).expect("admitted");
    let resp = slot
        .wait_timeout(Duration::from_secs(30))
        .expect("resolved — a panic never strands a claimed request");
    assert_eq!(resp.error, Some(InferError::WorkerPanicked));
    println!("  request 1: NACKed with {:?}", resp.error.unwrap());

    // Request 2 lands on the respawned worker and is served.
    let slot = server.submit(vec![3.0, 4.0]).expect("admitted");
    let resp = slot
        .wait_timeout(Duration::from_secs(30))
        .expect("served after respawn");
    assert!(resp.error.is_none());
    println!(
        "  request 2: served by the respawned worker -> {:?}",
        resp.output
    );

    let report = server.shutdown();
    println!(
        "  shutdown report: worker_panics={} restarts={} degraded={}",
        report.worker_panics,
        report.metrics.worker_restarts.load(Ordering::Relaxed),
        report.degraded
    );
    assert_eq!(report.worker_panics, 1);
    assert!(!report.degraded, "one panic is inside the restart budget");
    println!("  every request resolved; the panic cost one NACK, not a hang. ✓");
}
