//! END-TO-END driver: batched inference serving over the CMP fabric —
//! the paper's motivating "AI era" workload (§1), with all three layers
//! composing:
//!
//!   clients → Router (CMP shard queues) → dynamic Batcher
//!           → CMP work queue → Workers (PJRT executes the AOT-compiled
//!             JAX model whose hot-spot is the L1 Pallas kernel)
//!           → completion slots → clients
//!
//! Requires `make artifacts` (falls back to an echo engine otherwise so
//! the pipeline itself is still demonstrated). Reports throughput and
//! latency percentiles; the run is recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example serving_pipeline
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use cmpq::coordinator::batcher::BatchPolicy;
use cmpq::coordinator::router::RoutePolicy;
use cmpq::coordinator::server::{Server, ServerConfig};
use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
use cmpq::runtime::client::artifacts_dir;
use cmpq::runtime::{ModelRuntime, TestVectors};
use cmpq::util::XorShift64;

fn main() {
    let dir = artifacts_dir();
    // The stub ModelRuntime (no `pjrt` feature) cannot serve artifacts.
    let have_model = cfg!(feature = "pjrt") && dir.join("model.hlo.txt").exists();

    // --- Stage 0: prove the artifact's numerics before serving it.
    if have_model {
        let rt = ModelRuntime::load_from_artifacts(&dir).expect("load model");
        let tv = TestVectors::load(&dir).expect("load test vectors");
        let out = rt.infer(&tv.input).expect("inference");
        tv.check(&out).expect("JAX-vs-PJRT numerics");
        println!(
            "model ok: {:?} -> {:?}, matches JAX within rtol={}",
            rt.input_shape(),
            rt.output_shape(),
            tv.rtol
        );
    } else {
        println!("artifacts missing — run `make artifacts`; using echo engine");
    }

    let factory: EngineFactory = if have_model {
        let dir = dir.clone();
        Arc::new(move || {
            Ok(Box::new(ModelRuntime::load_from_artifacts(&dir)?) as Box<dyn InferenceEngine>)
        })
    } else {
        Arc::new(|| {
            Ok(Box::new(EchoEngine {
                batch: 8,
                features: 128,
                outputs: 16,
                scale: 1.0,
            }) as Box<dyn InferenceEngine>)
        })
    };

    // --- Stage 1: start the pipeline.
    let server = Arc::new(Server::start(
        ServerConfig {
            shards: 2,
            workers: 2,
            route_policy: RoutePolicy::RoundRobin,
            batch_policy: BatchPolicy {
                max_batch: 8, // = model batch
                max_wait: Duration::from_millis(2),
            },
            ..ServerConfig::default()
        },
        factory,
    ));

    // --- Stage 2: closed-loop clients.
    let n_clients = 8usize;
    let per_client = 64u64;
    let total = n_clients as u64 * per_client;
    println!("serving {total} requests from {n_clients} closed-loop clients...");
    let t0 = Instant::now();
    let clients: Vec<_> = (0..n_clients)
        .map(|c| {
            let server = server.clone();
            std::thread::spawn(move || {
                let mut rng = XorShift64::new(c as u64 + 1);
                let mut checksum = 0f64;
                for _ in 0..per_client {
                    let features: Vec<f32> =
                        (0..128).map(|_| rng.next_f64() as f32 - 0.5).collect();
                    let resp = server
                        .submit(features)
                        .expect("admitted (no admission limit configured)")
                        .wait_timeout(Duration::from_secs(120))
                        .expect("request timed out");
                    assert_eq!(resp.output.len(), 16, "one logit row");
                    checksum += resp.output[0] as f64;
                }
                checksum
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client panicked");
    }
    let elapsed = t0.elapsed();

    // --- Stage 3: report.
    println!(
        "\nthroughput: {total} requests in {elapsed:.2?} = {:.1} req/s",
        total as f64 / elapsed.as_secs_f64()
    );
    println!("pipeline metrics: {}", server.metrics().report());
    println!(
        "CMP work-queue footprint: {} nodes (bounded)",
        server.work_queue_footprint()
    );
    let server = Arc::try_unwrap(server).ok().expect("clients joined");
    let report = server.shutdown();
    assert!(report.clean(), "no panics, deaths, or drain NACKs");
    assert_eq!(
        report.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        total
    );
    println!("clean shutdown: all {total} requests completed. ✓");
}
