//! Multi-stage data pipeline on CMP queues — the producer/consumer
//! chains the paper's intro motivates (training-style ingestion:
//! decode → augment → batch), each stage a thread pool connected by
//! CMP queues, with backpressure via bounded node pools.
//!
//! ```sh
//! cargo run --release --example pipeline_stages
//! ```

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use cmpq::queue::cmp::{CmpConfig, CmpQueue, ReclaimTrigger};
use cmpq::util::XorShift64;

/// A "record" moving through the pipeline.
#[derive(Debug)]
struct Record {
    id: u64,
    payload: Vec<u8>,
    checksum: u64,
}

fn stage_queue() -> Arc<CmpQueue<Record>> {
    // Bounded pool ⇒ natural backpressure: a stage that outruns its
    // consumer hits the cap, reclaims, and retries (§3.3 Phase 1).
    Arc::new(CmpQueue::with_config(
        CmpConfig::default()
            .with_max_nodes(8192)
            .with_window(1024)
            .with_min_batch(16)
            .with_reclaim_period(512)
            .with_trigger(ReclaimTrigger::Modulo),
    ))
}

fn main() {
    let total: u64 = 100_000;
    let decode_q = stage_queue(); // source → decode
    let augment_q = stage_queue(); // decode → augment
    let sink_count = Arc::new(AtomicU64::new(0));
    let sink_checksum = Arc::new(AtomicU64::new(0));
    let done_decode = Arc::new(AtomicBool::new(false));
    let done_augment = Arc::new(AtomicBool::new(false));

    let t0 = Instant::now();

    // Stage 1: two source threads synthesize records.
    let sources: Vec<_> = (0..2u64)
        .map(|s| {
            let q = decode_q.clone();
            std::thread::spawn(move || {
                let mut rng = XorShift64::new(s + 1);
                for i in 0..total / 2 {
                    let id = s * (total / 2) + i;
                    let payload: Vec<u8> = (0..64).map(|_| rng.next_u64() as u8).collect();
                    q.push(Record {
                        id,
                        payload,
                        checksum: 0,
                    })
                    .expect("backpressure never fails permanently");
                }
            })
        })
        .collect();

    // Stage 2: three decoders compute checksums.
    let decoders: Vec<_> = (0..3)
        .map(|_| {
            let src = decode_q.clone();
            let dst = augment_q.clone();
            let done = done_decode.clone();
            std::thread::spawn(move || loop {
                match src.pop() {
                    Some(mut r) => {
                        r.checksum = r
                            .payload
                            .iter()
                            .fold(0u64, |a, &b| a.rotate_left(7) ^ b as u64);
                        dst.push(r).unwrap();
                    }
                    None => {
                        if done.load(Ordering::Acquire) && src.pop().is_none() {
                            return;
                        }
                        std::thread::yield_now();
                    }
                }
            })
        })
        .collect();

    // Stage 3: two augmenters/sinks fold results.
    let sinks: Vec<_> = (0..2)
        .map(|_| {
            let src = augment_q.clone();
            let done = done_augment.clone();
            let count = sink_count.clone();
            let sum = sink_checksum.clone();
            std::thread::spawn(move || loop {
                match src.pop() {
                    Some(r) => {
                        count.fetch_add(1, Ordering::AcqRel);
                        sum.fetch_xor(r.checksum ^ r.id, Ordering::AcqRel);
                    }
                    None => {
                        if done.load(Ordering::Acquire) && src.pop().is_none() {
                            return;
                        }
                        std::thread::yield_now();
                    }
                }
            })
        })
        .collect();

    for h in sources {
        h.join().unwrap();
    }
    done_decode.store(true, Ordering::Release);
    for h in decoders {
        h.join().unwrap();
    }
    done_augment.store(true, Ordering::Release);
    for h in sinks {
        h.join().unwrap();
    }
    let dt = t0.elapsed();

    let processed = sink_count.load(Ordering::Acquire);
    assert_eq!(processed, total, "every record reached the sink exactly once");
    println!(
        "3-stage pipeline: {total} records in {dt:.2?} ({:.2}M rec/s)",
        total as f64 / dt.as_secs_f64() / 1e6
    );
    println!("final checksum: {:#018x}", sink_checksum.load(Ordering::Acquire));
    println!(
        "stage-queue footprints: decode={} augment={} nodes (caps 8192 — backpressure held)",
        decode_q.footprint_nodes(),
        augment_q.footprint_nodes()
    );
    assert!(decode_q.footprint_nodes() <= 8192);
    assert!(augment_q.footprint_nodes() <= 8192);
    println!("decode stats:  {}", decode_q.stats().summary());
    println!("augment stats: {}", augment_q.stats().summary());
}
