//! Async serving: the pipeline without a thread per waiter.
//!
//! ```sh
//! cargo run --release --example async_serving
//! ```
//!
//! Demonstrates the executor-agnostic async bridge (DESIGN.md §10):
//! queue-level `pop_async` futures woken directly by pushes, the
//! server's async worker mode (N model workers as tasks on one host
//! thread), and `submit_async` clients keeping many requests in flight
//! from a single thread — all on the crate's own dependency-free
//! `block_on`/`Executor` (swap in any runtime; the futures only speak
//! `std::task::Waker`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use cmpq::coordinator::server::{Server, ServerConfig};
use cmpq::coordinator::worker::{EchoEngine, EngineFactory, InferenceEngine};
use cmpq::util::executor::{block_on, Executor};
use cmpq::CmpQueue;

fn main() {
    // 1. Queue-level async: a future resolves when a push lands — no
    //    parked thread, no polling loop.
    let q: Arc<CmpQueue<u64>> = Arc::new(CmpQueue::new());
    let q2 = q.clone();
    let consumer = std::thread::spawn(move || block_on(q2.pop_async()));
    while q.parked_consumers() == 0 {
        std::thread::yield_now(); // wait for the waker registration
    }
    q.push(7).unwrap();
    println!("pop_async resolved: {}", consumer.join().unwrap());

    // 2. The serving pipeline in async worker mode: 4 model workers as
    //    round-robin executor tasks multiplexed over ONE host thread.
    let factory: EngineFactory = Arc::new(|| {
        Ok(Box::new(EchoEngine {
            batch: 8,
            features: 16,
            outputs: 1,
            scale: 2.0,
        }) as Box<dyn InferenceEngine>)
    });
    let server = Arc::new(Server::start(
        ServerConfig {
            shards: 2,
            workers: 4,
            async_workers: true,
            ..ServerConfig::default()
        },
        factory,
    ));

    // 3. Async clients: 4 client tasks × 64 requests each, all in
    //    flight from one thread via `submit_async`.
    let total = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let mut clients = Executor::new();
    for c in 0..4u32 {
        let server = server.clone();
        let total = total.clone();
        clients.spawn(async move {
            for i in 0..64u32 {
                let x = (c * 64 + i) as f32;
                let resp = server.submit_async(vec![x; 16]).expect("admitted").await;
                assert_eq!(resp.output, vec![x * 2.0]);
                total.fetch_add(1, Ordering::Relaxed);
            }
        });
    }
    clients.run();
    let dt = t0.elapsed();
    let n = total.load(Ordering::Relaxed);
    println!(
        "async pipeline served {n} requests in {dt:.2?} ({:.0} req/s) \
         with 1 client thread + 1 worker thread",
        n as f64 / dt.as_secs_f64()
    );

    let server = Arc::try_unwrap(server).ok().expect("clients done");
    let report = server.shutdown();
    println!("{}", report.metrics.report());
}
