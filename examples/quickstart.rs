//! Quickstart: the CMP queue public API in five minutes.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;
use std::time::Instant;

use cmpq::queue::cmp::ReclaimTrigger;
use cmpq::{CmpConfig, CmpQueue, ConcurrentQueue};

fn main() {
    // 1. Default queue: unbounded, strict FIFO, lock-free.
    let q: CmpQueue<u64> = CmpQueue::new();
    for i in 0..10 {
        q.push(i).unwrap();
    }
    let drained: Vec<u64> = std::iter::from_fn(|| q.pop()).collect();
    assert_eq!(drained, (0..10).collect::<Vec<_>>());
    println!("FIFO drain: {drained:?}");

    // 2. Tuned queue: the paper's window sizing rule (§3.1) —
    //    W = max(MIN_WINDOW, expected_ops_per_sec × resilience_secs).
    let window = CmpConfig::window_for(1_000_000, 0.01); // 10ms resilience
    let cfg = CmpConfig::default()
        .with_window(window)
        .with_reclaim_period(2048)
        .with_trigger(ReclaimTrigger::Modulo);
    println!("window for 1M ops/s @ 10ms resilience: {window} cycles");

    // 3. MPMC: 4 producers, 4 consumers, zero coordination.
    let q = Arc::new(CmpQueue::<u64>::with_config(cfg));
    let total: u64 = 400_000;
    let per = total / 4;
    let t0 = Instant::now();
    let producers: Vec<_> = (0..4u64)
        .map(|p| {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..per {
                    q.push(p * per + i).unwrap();
                }
            })
        })
        .collect();
    let consumers: Vec<_> = (0..4)
        .map(|_| {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut n = 0u64;
                let mut checksum = 0u64;
                while n < per {
                    if let Some(v) = q.pop() {
                        checksum ^= v;
                        n += 1;
                    } else {
                        std::thread::yield_now();
                    }
                }
                (n, checksum)
            })
        })
        .collect();
    for h in producers {
        h.join().unwrap();
    }
    let consumed: u64 = consumers.into_iter().map(|h| h.join().unwrap().0).sum();
    let dt = t0.elapsed();
    assert_eq!(consumed, total);
    println!(
        "4P4C moved {total} items in {dt:.2?} ({:.2}M items/s)",
        total as f64 / dt.as_secs_f64() / 1e6
    );

    // 4. Introspection: bounded memory + operation stats.
    println!(
        "pool footprint: {} nodes (bounded by W + reclaim slack, not by {total})",
        q.footprint_nodes()
    );
    println!("stats: {}", q.stats().summary());

    // 5. The trait object view used by the benches.
    let dynq: Arc<dyn ConcurrentQueue<String>> = Arc::new(CmpQueue::new());
    dynq.enqueue("via trait".to_string());
    println!("trait dequeue: {:?}", dynq.try_dequeue());
}
