"""Pure-jnp oracle for the Pallas kernels — the CORE correctness signal
(pytest asserts kernel == ref across shape/dtype sweeps)."""

from __future__ import annotations

import jax.numpy as jnp


def gelu_ref(x):
    """tanh-approximation GELU, bit-matching the kernel's formula."""
    c = 0.7978845608028654  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def mlp_block_ref(x, w1, b1, w2, b2):
    """o = gelu(x @ W1 + b1) @ W2 + b2, accumulating in f32."""
    h = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1[None, :]
    h = gelu_ref(h)
    o = jnp.dot(h, w2, preferred_element_type=jnp.float32) + b2[None, :]
    return o.astype(x.dtype)


def layer_norm_ref(x, gamma, beta, eps: float = 1e-6):
    """Row-wise layer norm."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
