"""L1 — Pallas kernel: fused MLP block (Linear -> GELU -> Linear).

The compute hot-spot of the serving workload the coordinator drives
(DESIGN.md §1). TPU-style structure even though we execute through the
CPU PJRT client with ``interpret=True`` (real-TPU lowering emits Mosaic
custom-calls the CPU plugin cannot run — see /opt/xla-example/README):

* the batch dimension is tiled through the grid + ``BlockSpec`` so each
  step works on a VMEM-resident ``(TILE_B, D)`` activation tile — the
  HBM<->VMEM schedule a GPU implementation would express with
  threadblocks;
* both matmuls use ``preferred_element_type=float32`` (MXU accumulation
  width) and the weight operands are kept whole per grid step (they are
  small: D x H + H x D);
* dimensions default to multiples of 128 to match the MXU systolic
  array shape.

VMEM footprint per grid step (all f32, defaults TILE_B=8, D=128,
H=512): x tile 8*128*4 = 4 KiB, W1 128*512*4 = 256 KiB, W2 512*128*4 =
256 KiB, h 8*512*4 = 16 KiB, out 4 KiB, biases ~2.5 KiB -> ~540 KiB,
comfortably inside one TPU core's VMEM (16 MiB) with double-buffering
headroom. Recorded in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_B = 8


def _gelu(x):
    """tanh-approximation GELU (matches ref.py exactly)."""
    c = 0.7978845608028654  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One grid step: o = gelu(x @ W1 + b1) @ W2 + b2 on a batch tile."""
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = h + b1_ref[...][None, :]
    h = _gelu(h)
    o = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o = o + b2_ref[...][None, :]
    o_ref[...] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def mlp_block(x, w1, b1, w2, b2, *, tile_b: int = DEFAULT_TILE_B, interpret: bool = True):
    """Fused Linear->GELU->Linear over batch tiles.

    Args:
      x: ``(B, D)`` activations; ``B`` must be divisible by ``tile_b``.
      w1: ``(D, H)``;  b1: ``(H,)``;  w2: ``(H, D_out)``;  b2: ``(D_out,)``.
      tile_b: batch tile per grid step.
      interpret: must stay True for CPU-PJRT execution.

    Returns:
      ``(B, D_out)`` with ``x``'s dtype.
    """
    B, D = x.shape
    Dw, H = w1.shape
    H2, D_out = w2.shape
    if D != Dw or H != H2 or b1.shape != (H,) or b2.shape != (D_out,):
        raise ValueError(
            f"shape mismatch: x{x.shape} w1{w1.shape} b1{b1.shape} "
            f"w2{w2.shape} b2{b2.shape}"
        )
    if B % tile_b != 0:
        raise ValueError(f"batch {B} not divisible by tile_b {tile_b}")

    grid = (B // tile_b,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            # Activation tile marches down the batch.
            pl.BlockSpec((tile_b, D), lambda i: (i, 0)),
            # Weights/biases: whole array resident every step.
            pl.BlockSpec((D, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H, D_out), lambda i: (0, 0)),
            pl.BlockSpec((D_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_b, D_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D_out), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)


def vmem_bytes(tile_b: int, d: int, h: int, d_out: int, bytes_per_el: int = 4) -> int:
    """Estimated VMEM residency per grid step (perf-model input)."""
    x_tile = tile_b * d
    w1 = d * h
    b1 = h
    hidden = tile_b * h
    w2 = h * d_out
    b2 = d_out
    out = tile_b * d_out
    return (x_tile + w1 + b1 + hidden + w2 + b2 + out) * bytes_per_el
