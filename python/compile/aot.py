"""AOT compile path: lower the L2 model (with its L1 Pallas kernel) to
HLO **text** and emit artifacts the Rust runtime loads.

HLO text — NOT ``lowered.compile()`` output or ``.serialize()`` protos:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).

Outputs (``--out-dir``, default ../artifacts):
  model.hlo.txt      — batch-8 classifier forward (params baked in)
  synthload.hlo.txt  — compute-burn graph for the loaded regime
  testvec.json       — seeded input + expected output for the Rust
                       runtime integration test
  meta.json          — shapes/dtypes/artifact inventory

Usage: cd python && python -m compile.aot [--out-dir ../artifacts]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .model import ModelConfig, forward, forward_ref, init_params, synth_load

SYNTH_DIM = 64


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe path).

    ``print_large_constants=True`` is load-bearing: the default text
    dump elides big array constants as ``constant({...})`` and the
    XLA 0.5.1 text *parser* silently zero-fills them — baked model
    weights would all read as zeros on the Rust side.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def build_model_artifact(cfg: ModelConfig, seed: int):
    params = init_params(cfg, seed)

    def fn(x):
        return forward(x, params, cfg)

    spec = jax.ShapeDtypeStruct((cfg.batch, cfg.d_model), jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    hlo = to_hlo_text(lowered)

    # Deterministic test vectors, checked end-to-end from Rust.
    key = jax.random.PRNGKey(seed + 1)
    x = jax.random.normal(key, (cfg.batch, cfg.d_model), jnp.float32)
    y = fn(x)
    y_ref = forward_ref(x, params, cfg)
    import numpy as np

    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5)

    testvec = {
        "input_shape": list(x.shape),
        "output_shape": list(y.shape),
        "input": [float(v) for v in np.asarray(x).reshape(-1)],
        "expected": [float(v) for v in np.asarray(y).reshape(-1)],
        "rtol": 1e-4,
        "seed": seed,
    }
    return hlo, testvec


def build_synthload_artifact():
    spec = jax.ShapeDtypeStruct((SYNTH_DIM, SYNTH_DIM), jnp.float32)
    lowered = jax.jit(lambda x: (synth_load(x),)).lower(spec)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = ModelConfig()
    hlo, testvec = build_model_artifact(cfg, args.seed)
    model_path = os.path.join(args.out_dir, "model.hlo.txt")
    with open(model_path, "w") as f:
        f.write(hlo)
    print(f"wrote {model_path} ({len(hlo)} chars)")

    tv_path = os.path.join(args.out_dir, "testvec.json")
    with open(tv_path, "w") as f:
        json.dump(testvec, f)
    print(f"wrote {tv_path}")

    synth = build_synthload_artifact()
    synth_path = os.path.join(args.out_dir, "synthload.hlo.txt")
    with open(synth_path, "w") as f:
        f.write(synth)
    print(f"wrote {synth_path} ({len(synth)} chars)")

    from .kernels.mlp_block import vmem_bytes

    meta = {
        "model": {
            "path": "model.hlo.txt",
            "input_shape": [cfg.batch, cfg.d_model],
            "output_shape": [cfg.batch, cfg.n_classes],
            "dtype": "f32",
            "d_hidden": cfg.d_hidden,
            "tile_b": cfg.tile_b,
            "kernel_vmem_bytes_per_step": vmem_bytes(
                cfg.tile_b, cfg.d_model, cfg.d_hidden, cfg.d_model
            ),
        },
        "synthload": {
            "path": "synthload.hlo.txt",
            "input_shape": [SYNTH_DIM, SYNTH_DIM],
            "output_shape": [SYNTH_DIM, SYNTH_DIM],
            "dtype": "f32",
        },
        "jax_version": jax.__version__,
        "model_module": model_mod.__name__,
    }
    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
