"""L2 — JAX model: transformer-style MLP classifier block served by the
Rust coordinator (the inference workload of the paper's motivating "AI
era" pipelines, §1).

forward(x) = LayerNorm(x + MlpBlock(x)) @ W_out + b_out

The MLP block is the L1 Pallas kernel; the residual/norm/projection
stay plain jnp so the lowered HLO exercises both kernel and non-kernel
paths through the same artifact. Python runs at build time only.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.mlp_block import mlp_block
from .kernels.ref import layer_norm_ref, mlp_block_ref


class ModelConfig(NamedTuple):
    batch: int = 8
    d_model: int = 128
    d_hidden: int = 512
    n_classes: int = 16
    tile_b: int = 8


class Params(NamedTuple):
    w1: jax.Array  # (D, H)
    b1: jax.Array  # (H,)
    w2: jax.Array  # (H, D)
    b2: jax.Array  # (D,)
    gamma: jax.Array  # (D,)
    beta: jax.Array  # (D,)
    w_out: jax.Array  # (D, C)
    b_out: jax.Array  # (C,)


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Seeded, scale-sane initialization (fan-in scaled normals)."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    d, h, c = cfg.d_model, cfg.d_hidden, cfg.n_classes
    return Params(
        w1=jax.random.normal(k1, (d, h), jnp.float32) / jnp.sqrt(d),
        b1=jnp.zeros((h,), jnp.float32),
        w2=jax.random.normal(k2, (h, d), jnp.float32) / jnp.sqrt(h),
        b2=jnp.zeros((d,), jnp.float32),
        gamma=jnp.ones((d,), jnp.float32),
        beta=jnp.zeros((d,), jnp.float32),
        w_out=jax.random.normal(k3, (d, c), jnp.float32) / jnp.sqrt(d),
        b_out=jnp.zeros((c,), jnp.float32),
    )


def forward(x, params: Params, cfg: ModelConfig, *, interpret: bool = True):
    """Model forward pass: (B, D) -> (B, C) logits."""
    h = mlp_block(
        x,
        params.w1,
        params.b1,
        params.w2,
        params.b2,
        tile_b=cfg.tile_b,
        interpret=interpret,
    )
    h = x + h  # residual
    h = layer_norm_ref(h, params.gamma, params.beta)
    return jnp.dot(h, params.w_out) + params.b_out[None, :]


def forward_ref(x, params: Params, cfg: ModelConfig):
    """Oracle forward using the pure-jnp MLP reference."""
    h = mlp_block_ref(x, params.w1, params.b1, params.w2, params.b2)
    h = x + h
    h = layer_norm_ref(h, params.gamma, params.beta)
    return jnp.dot(h, params.w_out) + params.b_out[None, :]


def synth_load(x, steps: int = 8):
    """Build-time compute-burn graph for the PJRT-backed synthetic-load
    regime: an iterated matmul chain on a small square tile."""
    def body(_, acc):
        return jnp.tanh(acc @ acc.T) @ x / 8.0

    return jax.lax.fori_loop(0, steps, body, x)
