"""L1 correctness: Pallas kernel vs pure-jnp oracle — the core signal.

hypothesis sweeps shapes and dtypes; every case asserts allclose
against ref.py (the prompt's required methodology)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.mlp_block import mlp_block, vmem_bytes
from compile.kernels.ref import gelu_ref, mlp_block_ref


def make_inputs(b, d, h, d_out, dtype, seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4, k5 = jax.random.split(k, 5)
    x = jax.random.normal(k1, (b, d), jnp.float32).astype(dtype)
    w1 = (jax.random.normal(k2, (d, h), jnp.float32) / np.sqrt(d)).astype(dtype)
    b1 = (jax.random.normal(k3, (h,), jnp.float32) * 0.1).astype(dtype)
    w2 = (jax.random.normal(k4, (h, d_out), jnp.float32) / np.sqrt(h)).astype(dtype)
    b2 = (jax.random.normal(k5, (d_out,), jnp.float32) * 0.1).astype(dtype)
    return x, w1, b1, w2, b2


def tol_for(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


class TestKernelBasics:
    def test_matches_ref_default_shape(self):
        args = make_inputs(8, 128, 512, 128, jnp.float32)
        out = mlp_block(*args)
        ref = mlp_block_ref(*args)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol_for(jnp.float32))

    def test_output_shape_and_dtype(self):
        args = make_inputs(16, 64, 128, 32, jnp.float32)
        out = mlp_block(*args, tile_b=4)
        assert out.shape == (16, 32)
        assert out.dtype == jnp.float32

    def test_multiple_batch_tiles_consistent(self):
        """Tiling must not change the result: tile_b=2 vs tile_b=8."""
        args = make_inputs(16, 64, 128, 64, jnp.float32)
        a = mlp_block(*args, tile_b=2)
        b = mlp_block(*args, tile_b=8)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_rejects_bad_tile(self):
        args = make_inputs(10, 64, 128, 64, jnp.float32)
        with pytest.raises(ValueError, match="not divisible"):
            mlp_block(*args, tile_b=4)

    def test_rejects_shape_mismatch(self):
        x, w1, b1, w2, b2 = make_inputs(8, 64, 128, 64, jnp.float32)
        bad_b1 = jnp.zeros((b1.shape[0] + 1,), b1.dtype)
        with pytest.raises(ValueError, match="shape mismatch"):
            mlp_block(x, w1, bad_b1, w2, b2)

    def test_gelu_ref_known_values(self):
        x = jnp.array([0.0, 1.0, -1.0, 3.0])
        g = np.asarray(gelu_ref(x))
        assert g[0] == 0.0
        assert abs(g[1] - 0.8412) < 1e-3
        assert abs(g[2] + 0.1588) < 1e-3
        assert abs(g[3] - 2.9964) < 1e-3

    def test_zero_input_gives_bias_path(self):
        x, w1, b1, w2, b2 = make_inputs(8, 64, 128, 64, jnp.float32)
        x = jnp.zeros_like(x)
        out = mlp_block(x, w1, b1, w2, b2)
        ref = mlp_block_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b_tiles=st.integers(min_value=1, max_value=4),
    tile_b=st.sampled_from([1, 2, 4, 8]),
    d=st.sampled_from([8, 32, 64, 128]),
    h=st.sampled_from([16, 64, 256]),
    d_out=st.sampled_from([8, 32, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_shape_sweep(b_tiles, tile_b, d, h, d_out, seed):
    """hypothesis: kernel == ref across the shape lattice (f32)."""
    b = b_tiles * tile_b
    args = make_inputs(b, d, h, d_out, jnp.float32, seed=seed)
    out = mlp_block(*args, tile_b=tile_b)
    ref = mlp_block_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol_for(jnp.float32))


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    tile_b=st.sampled_from([2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_dtype_sweep(dtype, tile_b, seed):
    """hypothesis: dtype sweep (f32 + bf16) at a fixed MXU-ish shape."""
    args = make_inputs(8, 64, 128, 64, dtype, seed=seed)
    out = mlp_block(*args, tile_b=tile_b)
    ref = mlp_block_ref(*args)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32),
        np.asarray(ref, dtype=np.float32),
        **tol_for(dtype),
    )


class TestVmemModel:
    def test_default_config_fits_vmem(self):
        """Shipped config must fit a TPU core's VMEM with headroom."""
        bytes_ = vmem_bytes(8, 128, 512, 128)
        assert bytes_ < 2 * 1024 * 1024, f"{bytes_} exceeds 2 MiB budget"

    def test_scales_linearly_in_tile(self):
        a = vmem_bytes(8, 128, 512, 128)
        b = vmem_bytes(16, 128, 512, 128)
        # Only activation tiles scale; weights dominate and are constant.
        assert b > a
        assert b - a == (8 * 128 + 8 * 512 + 8 * 128) * 4
