"""AOT path: HLO text is emitted, parses as HLO (sanity markers), and
the test vectors are self-consistent with the oracle."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import build_model_artifact, build_synthload_artifact, to_hlo_text
from compile.model import ModelConfig, forward_ref, init_params


def test_model_hlo_text_structure():
    hlo, testvec = build_model_artifact(ModelConfig(), seed=0)
    assert "HloModule" in hlo, "must be HLO text"
    assert "ENTRY" in hlo
    # f32[8,128] input must appear in the entry signature.
    assert "f32[8,128]" in hlo
    # Output: tuple'd f32[8,16].
    assert "f32[8,16]" in hlo
    assert len(hlo) > 1000


def test_model_testvec_consistent_with_ref():
    cfg = ModelConfig()
    hlo, tv = build_model_artifact(cfg, seed=0)
    assert tv["input_shape"] == [cfg.batch, cfg.d_model]
    assert tv["output_shape"] == [cfg.batch, cfg.n_classes]
    x = jnp.asarray(tv["input"], jnp.float32).reshape(cfg.batch, cfg.d_model)
    params = init_params(cfg, seed=tv["seed"])
    y_ref = np.asarray(forward_ref(x, params, cfg)).reshape(-1)
    np.testing.assert_allclose(
        np.asarray(tv["expected"]), y_ref, rtol=1e-4, atol=1e-4
    )


def test_testvec_is_seed_stable():
    _, a = build_model_artifact(ModelConfig(), seed=0)
    _, b = build_model_artifact(ModelConfig(), seed=0)
    assert a["input"] == b["input"]
    assert a["expected"] == b["expected"]


def test_synthload_hlo_structure():
    hlo = build_synthload_artifact()
    assert "HloModule" in hlo
    assert "f32[64,64]" in hlo


def test_to_hlo_text_simple_function():
    lowered = jax.jit(lambda x: (x * 2.0 + 1.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    hlo = to_hlo_text(lowered)
    assert "HloModule" in hlo
    assert "f32[4]" in hlo


def test_hlo_prints_large_constants():
    """Regression guard: the default as_hlo_text() elides big constants
    as ``constant({...})`` which XLA 0.5.1's text parser zero-fills —
    the baked weights would silently become zeros in Rust."""
    hlo, _ = build_model_artifact(ModelConfig(), seed=0)
    assert "constant({...})" not in hlo, "weights were elided from the HLO text"


def test_hlo_has_no_serialized_proto_markers():
    """Guard the text-interchange invariant (DESIGN.md; xla 0.5.1 would
    reject 64-bit-id protos — we must never ship .serialize output)."""
    hlo, _ = build_model_artifact(ModelConfig(), seed=0)
    assert hlo.isprintable() or "\n" in hlo  # text, not binary
    assert not hlo.startswith("\x08"), "looks like a binary proto!"


@pytest.mark.parametrize("seed", [0, 1, 42])
def test_different_seeds_change_expected(seed):
    _, tv = build_model_artifact(ModelConfig(), seed=seed)
    assert tv["seed"] == seed
    assert len(tv["expected"]) == 8 * 16


def test_artifact_roundtrip_via_files(tmp_path):
    """End-to-end emission: run main() logic against a tmp dir."""
    import subprocess
    import sys
    import os

    out = tmp_path / "artifacts"
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    for f in ["model.hlo.txt", "synthload.hlo.txt", "testvec.json", "meta.json"]:
        assert (out / f).exists(), f
    meta = json.loads((out / "meta.json").read_text())
    assert meta["model"]["input_shape"] == [8, 128]
    assert meta["model"]["kernel_vmem_bytes_per_step"] < 2 * 1024 * 1024
