"""L2 correctness: model forward (kernel path) vs oracle, shapes,
determinism, and the synthetic-load graph."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    ModelConfig,
    Params,
    forward,
    forward_ref,
    init_params,
    synth_load,
)


def test_forward_matches_ref():
    cfg = ModelConfig()
    params = init_params(cfg, seed=0)
    x = jax.random.normal(jax.random.PRNGKey(1), (cfg.batch, cfg.d_model), jnp.float32)
    y = forward(x, params, cfg)
    y_ref = forward_ref(x, params, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5)


def test_forward_shape():
    cfg = ModelConfig()
    params = init_params(cfg)
    x = jnp.zeros((cfg.batch, cfg.d_model), jnp.float32)
    y = forward(x, params, cfg)
    assert y.shape == (cfg.batch, cfg.n_classes)
    assert y.dtype == jnp.float32


def test_params_are_seed_deterministic():
    cfg = ModelConfig()
    a = init_params(cfg, seed=7)
    b = init_params(cfg, seed=7)
    c = init_params(cfg, seed=8)
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    assert not np.array_equal(np.asarray(a.w1), np.asarray(c.w1))


def test_params_shapes():
    cfg = ModelConfig(batch=8, d_model=32, d_hidden=64, n_classes=4, tile_b=4)
    p = init_params(cfg)
    assert isinstance(p, Params)
    assert p.w1.shape == (32, 64)
    assert p.w2.shape == (64, 32)
    assert p.w_out.shape == (32, 4)
    assert p.gamma.shape == (32,)


def test_forward_nontrivial_logits():
    cfg = ModelConfig()
    params = init_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (cfg.batch, cfg.d_model), jnp.float32)
    y = np.asarray(forward(x, params, cfg))
    assert np.all(np.isfinite(y))
    assert y.std() > 1e-3, "logits should vary"
    # Rows differ (model is input-dependent).
    assert not np.allclose(y[0], y[1])


def test_smaller_config_forward():
    cfg = ModelConfig(batch=4, d_model=16, d_hidden=32, n_classes=8, tile_b=2)
    params = init_params(cfg, seed=2)
    x = jax.random.normal(jax.random.PRNGKey(4), (cfg.batch, cfg.d_model), jnp.float32)
    y = forward(x, params, cfg)
    y_ref = forward_ref(x, params, cfg)
    assert y.shape == (4, 8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5)


def test_synth_load_is_finite_and_shaped():
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 64), jnp.float32) * 0.1
    y = synth_load(x, steps=4)
    assert y.shape == (64, 64)
    assert np.all(np.isfinite(np.asarray(y)))
